"""Unified pipeline API: operating points, plan compilation, batched decode
round-trips (property-based), capability negotiation, deprecation shims."""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from hypothesis_compat import given, settings, st  # noqa: E402

from repro import pipeline  # noqa: E402
from repro.pipeline import (Capabilities, ModelSpec, NegotiationError,
                            OperatingPoint, negotiate)  # noqa: E402

RNG = np.random.default_rng(7)


def _z(b, h, w, p):
    """A split activation with per-channel scale variety (exercises the
    per-example side info)."""
    scale = RNG.uniform(0.1, 4.0, size=(1, 1, 1, p)).astype(np.float32)
    return (RNG.normal(size=(b, h, w, p)).astype(np.float32) * scale)


def _spec(c):
    return ModelSpec(sel_idx=np.arange(c))


# ---------------------------------------------------------------------------
# Operating-point resolution
# ---------------------------------------------------------------------------

def test_op_resolves_tiling_and_context_from_backend():
    assert OperatingPoint(c=8, bits=8).resolve().tiling == "tiled"
    assert OperatingPoint(c=8, bits=8, backend="rans").resolve().tiling == \
        "direct"
    assert OperatingPoint(c=8, bits=8, backend="rans").resolve().context == \
        "static"
    assert OperatingPoint(c=8, bits=8, backend="rans-ctx").resolve().context \
        == "adaptive"
    # 'adaptive' context upgrades the rans family on the wire
    op = OperatingPoint(c=8, bits=8, backend="rans", context="adaptive")
    assert op.wire_backend == "rans-ctx"


def test_op_tiled_backend_requires_power_of_two_c():
    with pytest.raises(ValueError, match="power-of-two"):
        OperatingPoint(c=3, bits=8, backend="zlib").resolve()
    # direct backends take any C
    assert OperatingPoint(c=3, bits=8, backend="rans").resolve().c == 3


def test_op_validates_fields():
    with pytest.raises(ValueError):
        OperatingPoint(c=0, bits=8)
    with pytest.raises(ValueError):
        OperatingPoint(c=8, bits=0)
    with pytest.raises(ValueError):
        OperatingPoint(c=8, bits=8, tiling="sideways")


def test_unknown_backend_fails_at_compile_time():
    with pytest.raises(ValueError, match="unknown backend"):
        pipeline.compile(OperatingPoint(c=4, bits=8, backend="brotli"),
                         _spec(4))


# ---------------------------------------------------------------------------
# Plan compilation and caching
# ---------------------------------------------------------------------------

def test_compile_is_cached_per_op_and_spec():
    spec = _spec(8)
    op = OperatingPoint(c=8, bits=6)
    assert pipeline.compile(op, spec) is pipeline.compile(op, spec)
    assert pipeline.compile(op, spec) is not pipeline.compile(op, _spec(8))
    op2 = OperatingPoint(c=8, bits=4)
    assert pipeline.compile(op, spec) is not pipeline.compile(op2, spec)


def test_plan_rejects_mismatched_channel_count():
    with pytest.raises(ValueError, match="C=8"):
        pipeline.compile(OperatingPoint(c=8, bits=6), _spec(4))


def test_weightless_plan_encodes_but_refuses_restore():
    plan = pipeline.compile(OperatingPoint(c=4, bits=6), _spec(4))
    blob = plan.encode(_z(1, 4, 4, 8))
    dec = plan.decode(blob)
    assert dec.codes.shape == (1, 4, 4, 4)
    with pytest.raises(ValueError, match="without model weights"):
        plan.restore(dec)


# ---------------------------------------------------------------------------
# Round trips: decode_batch(encode(z)) is bit-exact
# ---------------------------------------------------------------------------

BACKENDS = ["raw", "zlib", "png", "rans", "rans-ctx"]


@pytest.mark.parametrize("backend", BACKENDS)
def test_plan_round_trip_bit_exact(backend):
    plan = pipeline.compile(
        OperatingPoint(c=8, bits=6, backend=backend), _spec(8))
    z = _z(2, 5, 3, 16)
    codes, mins, maxs = plan.quantize(z)
    dec = plan.decode_batch([plan.encode(z)])
    np.testing.assert_array_equal(dec.codes, codes)
    np.testing.assert_array_equal(dec.mins, mins)
    np.testing.assert_array_equal(dec.maxs, maxs)


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_plan_round_trip_property(data):
    """decode_batch(encode(z)) == the quantizer's own codes/side-info for
    every registered backend, odd shapes included."""
    backend = data.draw(st.sampled_from(BACKENDS), label="backend")
    direct = backend.startswith("rans")
    c = data.draw(st.sampled_from([1, 2, 3, 5, 8] if direct
                                  else [1, 2, 4, 8]), label="c")
    bits = data.draw(st.integers(2, 8), label="bits")
    b = data.draw(st.integers(1, 2), label="b")
    h = data.draw(st.integers(1, 6), label="h")
    w = data.draw(st.integers(1, 6), label="w")
    n_blobs = data.draw(st.integers(1, 3), label="n_blobs")
    plan = pipeline.compile(
        OperatingPoint(c=c, bits=bits, backend=backend), _spec(c))
    zs = [_z(b, h, w, c + 2) for _ in range(n_blobs)]
    refs = [plan.quantize(z) for z in zs]
    dec = plan.decode_batch([plan.encode(z) for z in zs])
    np.testing.assert_array_equal(
        dec.codes, np.concatenate([r[0] for r in refs]))
    np.testing.assert_array_equal(
        dec.mins, np.concatenate([r[1] for r in refs]))
    np.testing.assert_array_equal(
        dec.maxs, np.concatenate([r[2] for r in refs]))


@given(st.data())
@settings(max_examples=10, deadline=None)
def test_mixed_operating_points_in_one_arrival_batch(data):
    """A shuffled stream of blobs at mixed operating points, grouped by
    bucket (as the gateway's batcher does), decodes bit-exactly per group."""
    ops = [
        OperatingPoint(c=4, bits=4),
        OperatingPoint(c=4, bits=6, backend="raw"),
        OperatingPoint(c=8, bits=4, backend="rans"),
        OperatingPoint(c=2, bits=8, backend="rans-ctx"),
    ]
    stream = []
    for _ in range(data.draw(st.integers(4, 8), label="n")):
        op = data.draw(st.sampled_from(ops), label="op")
        plan = pipeline.compile(op, _spec(op.c))
        z = _z(1, 4, 4, 10)
        stream.append((plan, plan.encode(z), plan.quantize(z)))
    groups = {}
    for plan, blob, ref in stream:
        groups.setdefault((plan.op, blob.shape), []).append(
            (plan, blob, ref))
    for (_, _), members in groups.items():
        plan = members[0][0]
        dec = plan.decode_batch([blob for _, blob, _ in members])
        np.testing.assert_array_equal(
            dec.codes, np.concatenate([ref[0] for _, _, ref in members]))
        np.testing.assert_array_equal(
            dec.mins, np.concatenate([ref[1] for _, _, ref in members]))


def test_mixed_operating_points_deterministic():
    """Deterministic twin of the property test above (runs without
    hypothesis): interleaved ops and odd shapes, grouped then batch-decoded."""
    ops = [OperatingPoint(c=4, bits=4),
           OperatingPoint(c=8, bits=6, backend="raw"),
           OperatingPoint(c=3, bits=5, backend="rans"),
           OperatingPoint(c=4, bits=8, backend="rans-ctx")]
    stream = []
    for i in range(9):
        op = ops[i % len(ops)]
        plan = pipeline.compile(op, _spec(op.c))
        z = _z(1, 5, 3, 10)
        stream.append((plan, plan.encode(z), plan.quantize(z)))
    groups = {}
    for item in stream:
        groups.setdefault((item[0].op, item[1].shape), []).append(item)
    assert len(groups) == len(ops)
    for members in groups.values():
        plan = members[0][0]
        dec = plan.decode_batch([blob for _, blob, _ in members])
        np.testing.assert_array_equal(
            dec.codes, np.concatenate([ref[0] for _, _, ref in members]))
        np.testing.assert_array_equal(
            dec.mins, np.concatenate([ref[1] for _, _, ref in members]))
        np.testing.assert_array_equal(
            dec.maxs, np.concatenate([ref[2] for _, _, ref in members]))


def test_decode_batch_rejects_heterogeneous_blobs():
    plan4 = pipeline.compile(OperatingPoint(c=4, bits=6), _spec(4))
    plan8 = pipeline.compile(OperatingPoint(c=8, bits=6), _spec(8))
    b4 = plan4.encode(_z(1, 4, 4, 8))
    b8 = plan8.encode(_z(1, 4, 4, 8))
    with pytest.raises(ValueError, match="this plan executes"):
        plan4.decode_batch([b4, b8])
    small = plan4.encode(_z(1, 2, 2, 8))
    with pytest.raises(ValueError, match="mixed shapes"):
        plan4.decode_batch([b4, small])
    with pytest.raises(ValueError, match="at least one"):
        plan4.decode_batch([])


def test_wire_blob_parses_and_validates():
    plan = pipeline.compile(OperatingPoint(c=4, bits=6), _spec(4))
    blob = plan.encode(_z(1, 3, 3, 6))
    enc = blob.to_tensor()
    assert enc.bits == 6
    assert blob.nbytes == len(blob.data)
    corrupt = pipeline.WireBlob(data=blob.data[:-1], op=blob.op,
                                shape=blob.shape)
    with pytest.raises(ValueError):
        plan.decode(corrupt)


def test_blob_from_tensor_bridges_legacy_wire_tensors():
    for backend in ("zlib", "rans"):
        op = OperatingPoint(c=4, bits=6, backend=backend)
        plan = pipeline.compile(op, _spec(4))
        z = _z(2, 4, 4, 8)
        blob = plan.encode(z)
        bridged = pipeline.blob_from_tensor(blob.to_tensor(), op, batch=2)
        assert tuple(bridged.shape) == tuple(blob.shape)
        dec_a = plan.decode(blob)
        dec_b = plan.decode(bridged)
        np.testing.assert_array_equal(dec_a.codes, dec_b.codes)


# ---------------------------------------------------------------------------
# Capability negotiation
# ---------------------------------------------------------------------------

def test_negotiate_passes_through_supported_points():
    op = OperatingPoint(c=8, bits=8, backend="rans")
    assert negotiate(op, None) is op
    assert negotiate(op, Capabilities()) is op
    assert negotiate(op, Capabilities(backends=("rans", "zlib"))) is op


def test_negotiate_downgrades_backend_to_preferred():
    op = OperatingPoint(c=8, bits=8, backend="rans")
    out = negotiate(op, Capabilities(backends=("zlib",)))
    assert out.backend == "zlib" and (out.c, out.bits) == (8, 8)


def test_negotiate_clamps_bits():
    op = OperatingPoint(c=8, bits=12, backend="rans")
    out = negotiate(op, Capabilities(max_bits=8))
    assert out.bits == 8


def test_negotiate_refuses_without_downgrade():
    op = OperatingPoint(c=8, bits=8, backend="rans")
    with pytest.raises(NegotiationError):
        negotiate(op, Capabilities(backends=("zlib",), downgrade=False))
    with pytest.raises(NegotiationError):
        negotiate(op, Capabilities(max_bits=4, downgrade=False))


def test_negotiate_always_refuses_foreign_wire_profile():
    op = OperatingPoint(c=8, bits=8, profile=1)
    with pytest.raises(NegotiationError, match="profile"):
        negotiate(op, Capabilities())          # downgrade=True cannot help


def test_negotiate_refuses_unresolvable_downgrade():
    """A downgrade landing on a backend that cannot code this C (tiled zlib
    needs power-of-two C) must refuse with NegotiationError — not report
    success and blow up with a ValueError at plan-compile time."""
    op = OperatingPoint(c=12, bits=8, backend="rans")   # legal: rans is direct
    with pytest.raises(NegotiationError, match="no supported backend"):
        negotiate(op, Capabilities(backends=("zlib",)))
    # with a direct backend in the caps, the same point negotiates fine
    out = negotiate(op, Capabilities(backends=("rans",)))
    assert out.c == 12


def test_negotiate_checks_wire_backend_not_family():
    # caps that speak 'rans' but not 'rans-ctx' must catch the upgrade
    op = OperatingPoint(c=8, bits=8, backend="rans", context="adaptive")
    out = negotiate(op, Capabilities(backends=("rans",)))
    assert out.wire_backend == "rans"


# (the one-release encode_activation/decode_stream shims are gone; their
# absence is pinned in tests/test_no_deprecations.py)
