"""Regression tests for the two serving-tier poisons fixed in this release:

1. fp16-overflow NaNs: ``nextafter(mx, inf)`` at the finite fp16 extremes
   (±65504) used to yield ``inf`` side info, which zeroed every code and
   dequantized to NaN. All three quantize paths (core/quant, the Pallas
   kernel, the pod-boundary stream path) now saturate the widened bound at
   ±65504 with bit-identical math.

2. Stale channel-budget accounting: ``SimulatedChannel.transmit`` left
   ``now`` behind ``t_done`` for packets spanning several budget ticks, so
   the no-arg ``budget_remaining()`` read a tick the wire had already blown
   past. The clock now advances through the whole transmission; explicit
   ``at=`` call sites are unchanged bit for bit.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.quant import compute_quant_params, dequantize, quantize
from repro.distributed.pipeline import _dequantize_stream, _quantize_stream
from repro.kernels.quantize import quantize_pallas
from repro.serve.channel import ChannelConfig, SimulatedChannel

F16_MAX = 65504.0
F16_SUBNORMAL = 6e-8          # well inside fp16's subnormal range


def _roundtrip_tol(qp):
    """Half a quantizer step plus the fp16 rounding slack on the bounds.

    fp16-rounding the min can land *above* a data point (clip error up to
    half an ulp of the bound), so the bound is 0.5*step + ulp(side info)."""
    step = np.asarray(qp.step(), np.float64)
    ulp = (np.abs(np.asarray(qp.mins, np.float64))
           + np.abs(np.asarray(qp.maxs, np.float64))) * 2.0 ** -10
    return 0.5001 * step + ulp


# ---------------------------------------------------------------------------
# fp16 overflow: core/quant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 8])
def test_fp16_extremes_round_trip_losslessly_core(bits):
    """±65504 channels keep finite side info and recover exactly: the
    endpoints map to codes 0 / 2^n - 1 whose dequantization is the bound."""
    x = jnp.asarray([[-F16_MAX, 0.0], [F16_MAX, F16_SUBNORMAL]], jnp.float32)
    qp = compute_quant_params(x, bits)
    assert bool(jnp.all(jnp.isfinite(qp.mins.astype(jnp.float32))))
    assert bool(jnp.all(jnp.isfinite(qp.maxs.astype(jnp.float32))))
    codes = quantize(x, qp)
    assert int(codes.max()) <= qp.levels
    deq = np.asarray(dequantize(codes, qp))
    assert np.all(np.isfinite(deq))
    # exact at the extremes (range [-65504, 65504] divides evenly)
    assert deq[0, 0] == -F16_MAX
    assert deq[1, 0] == F16_MAX


def test_issue_repro_no_nan():
    """The exact tensor from the bug report: a channel whose max is fp16-max
    used to produce maxs=inf -> codes all 0 -> NaN out of dequantize."""
    x = jnp.asarray([[0.0, 60000.0], [F16_MAX, -5.0]], jnp.float32)
    qp = compute_quant_params(x, 8)
    side = np.stack([np.asarray(qp.mins, np.float32),
                     np.asarray(qp.maxs, np.float32)])
    assert np.all(np.isfinite(side)), side
    deq = np.asarray(dequantize(quantize(x, qp), qp))
    assert np.all(np.isfinite(deq))
    assert np.all(np.abs(deq - np.asarray(x)) <= _roundtrip_tol(qp))


def test_beyond_fp16_range_saturates_finite():
    """Values past fp16's range cast to ±inf; the bounds must clamp to
    ±65504 and the round-trip stays finite (saturating, not exact)."""
    x = jnp.asarray([[-70000.0, 1.0], [70000.0, -1.0]], jnp.float32)
    qp = compute_quant_params(x, 8)
    assert float(qp.maxs.astype(jnp.float32).max()) == F16_MAX
    assert float(qp.mins.astype(jnp.float32).min()) == -F16_MAX
    deq = np.asarray(dequantize(quantize(x, qp), qp))
    assert np.all(np.isfinite(deq))
    assert deq.max() == F16_MAX and deq.min() == -F16_MAX


def test_per_example_extremes_finite():
    x = jnp.full((2, 3, 3, 4), F16_MAX, jnp.float32)
    x = x.at[1].multiply(-1.0)
    qp = compute_quant_params(x, 8, per_example=True)
    assert bool(jnp.all(jnp.isfinite(qp.maxs.astype(jnp.float32))))
    deq = np.asarray(dequantize(quantize(x, qp), qp))
    assert np.all(np.isfinite(deq))


# ---------------------------------------------------------------------------
# fp16 overflow: Pallas kernel vs reference, stream path
# ---------------------------------------------------------------------------

def _extreme_tensor(b, r, c, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(scale=3.0, size=(b, r, c)).astype(np.float32)
    specials = np.asarray([F16_MAX, -F16_MAX, 70000.0, -70000.0,
                           F16_SUBNORMAL, -F16_SUBNORMAL, 0.0, 1.0],
                          np.float32)
    flat = x.reshape(-1)
    idx = rng.permutation(flat.size)[:specials.size * 4]
    flat[idx] = np.tile(specials, 4)
    return jnp.asarray(flat.reshape(b, r, c))


def test_pallas_kernel_matches_reference_at_extremes():
    """The Pallas quantizer and the jnp reference stay bit-identical through
    the saturation fix (codes, mins, and maxs all exact)."""
    x = _extreme_tensor(2, 64, 8)
    codes_p, mins_p, maxs_p = quantize_pallas(x, 8, block_c=8)
    qp = compute_quant_params(x, 8, per_example=True)       # (B, 1, C) side
    codes_r = quantize(x, qp)
    assert np.array_equal(np.asarray(codes_p), np.asarray(codes_r))
    assert np.array_equal(np.asarray(mins_p),
                          np.asarray(qp.mins).reshape(mins_p.shape))
    assert np.array_equal(np.asarray(maxs_p),
                          np.asarray(qp.maxs).reshape(maxs_p.shape))
    assert np.all(np.isfinite(np.asarray(maxs_p, np.float32)))


def test_stream_path_extremes_round_trip():
    """The pod-boundary stream quantizer carries the same fix: finite side
    info and lossless recovery of the fp16 extremes."""
    x = jnp.asarray([[-F16_MAX, 0.0, F16_SUBNORMAL],
                     [F16_MAX, 1.0, -F16_SUBNORMAL]], jnp.float32)
    codes, mn, mx = _quantize_stream(x, 8)
    assert np.all(np.isfinite(np.asarray(mn, np.float32)))
    assert np.all(np.isfinite(np.asarray(mx, np.float32)))
    deq = np.asarray(_dequantize_stream(codes, mn, mx, 8, jnp.float32))
    assert np.all(np.isfinite(deq))
    assert deq[0, 0] == -F16_MAX and deq[1, 0] == F16_MAX


@settings(max_examples=25, deadline=None)
@given(scale=st.sampled_from([F16_MAX, 4096.0, 1.0, 1e-3, F16_SUBNORMAL]),
       offset=st.sampled_from([0.0, -1.0, 0.5]),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_roundtrip_property_extreme_dynamic_ranges(scale, offset, seed):
    """Property: over any dynamic range up to full fp16 (including the
    subnormal regime), every path yields finite side info and a round-trip
    error within half a quantizer step (+ fp16 bound slack); the core
    per-channel path and the stream path agree bit for bit."""
    rng = np.random.default_rng(seed)
    x_np = (rng.uniform(-1.0, 1.0, size=(3, 5, 4)) + offset) * scale
    x_np = x_np.astype(np.float32)
    x_np[0, 0, 0] = scale                 # pin the exact extremes
    x_np[0, 1, 1] = -scale
    x = jnp.asarray(x_np)

    qp = compute_quant_params(x.reshape(-1, 4), 8)
    assert bool(jnp.all(jnp.isfinite(qp.mins.astype(jnp.float32))))
    assert bool(jnp.all(jnp.isfinite(qp.maxs.astype(jnp.float32))))
    codes = quantize(x.reshape(-1, 4), qp)
    deq = np.asarray(dequantize(codes, qp))
    assert np.all(np.isfinite(deq))
    assert np.all(np.abs(deq - x_np.reshape(-1, 4)) <= _roundtrip_tol(qp))

    s_codes, s_mn, s_mx = _quantize_stream(x.reshape(-1, 4), 8)
    assert np.array_equal(np.asarray(s_codes), np.asarray(codes))
    assert np.array_equal(np.asarray(s_mn), np.asarray(qp.mins))
    assert np.array_equal(np.asarray(s_mx), np.asarray(qp.maxs))

    p_codes, p_mn, p_mx = quantize_pallas(x, 8, block_c=4)
    qpe = compute_quant_params(x, 8, per_example=True)
    assert np.array_equal(np.asarray(p_codes), np.asarray(quantize(x, qpe)))
    assert np.all(np.isfinite(np.asarray(p_mx, np.float32)))


# ---------------------------------------------------------------------------
# channel budget: the clock commits to the transmission it planned
# ---------------------------------------------------------------------------

def _metered(per_tick=1000, bw=1000.0, latency=0.0):
    cfg = ChannelConfig(bandwidth_bps=bw, base_latency_s=latency,
                        tick_s=1.0, budget_bits_per_tick=per_tick)
    return SimulatedChannel(cfg)


def test_spanning_packet_commits_clock_and_budget():
    """A 2500-bit packet over a 1000-bit/tick link spends ticks 0..2 and
    finishes at t=2.5; the no-arg budget must read tick 2's remaining 500
    bits, not tick 0 (which the wire already blew past)."""
    ch = _metered()
    tx = ch.transmit(2500)
    assert tx.t_start == 0.0
    assert tx.t_arrive == 2.5
    assert ch.now == 2.5
    assert ch.budget_remaining() == 500
    assert ch.budget_remaining() == ch.budget_remaining(at=ch.now)
    # explicit at= reads are unchanged: tick 0 is fully spent, tick 3 fresh
    assert ch.budget_remaining(at=0.0) == 0
    assert ch.budget_remaining(at=3.2) == 1000


def test_budget_monotonic_under_multi_tick_packets():
    """The clock never runs behind the wire, and the no-arg budget always
    describes the tick containing ``now`` — across a mix of sub-tick and
    multi-tick packets."""
    ch = _metered()
    prev_now = 0.0
    for bits in (300, 2500, 100, 4000, 999):
        tx = ch.transmit(bits)
        assert ch.now >= prev_now
        assert ch.now >= tx.t_start
        prev_now = ch.now
        rem = ch.budget_remaining()
        assert 0 <= rem <= ch.cfg.budget_bits_per_tick
        tick = int(math.floor(ch.now / ch.cfg.tick_s))
        assert rem == (ch.cfg.budget_bits_per_tick
                       - ch._tick_used.get(tick, 0))


def test_explicit_at_call_sites_bit_identical():
    """Transmission timestamps and ``at=`` budget reads never depended on
    ``now``; pin the exact pre-fix values so the fix cannot drift them."""
    cfg = ChannelConfig(bandwidth_bps=1000.0, base_latency_s=0.01,
                        tick_s=1.0, budget_bits_per_tick=1000)
    ch = SimulatedChannel(cfg)
    tx1 = ch.transmit(600, 0.0)
    assert (tx1.t_submit, tx1.t_start, tx1.t_arrive) == (0.0, 0.0, 0.61)
    # 400 bits left in tick 0 < 600: defer to tick 1, wire free at 0.6
    tx2 = ch.transmit(600, 0.1)
    assert (tx2.t_submit, tx2.t_start, tx2.t_arrive) == (0.1, 1.0, 1.61)
    assert ch.budget_remaining(at=0.5) == 400
    assert ch.budget_remaining(at=1.5) == 400
    assert ch.now == 1.6


def test_advance_still_moves_past_committed_clock():
    ch = _metered()
    ch.transmit(2500)
    ch.advance(0.5)
    assert ch.now == 3.0
    assert ch.budget_remaining() == 1000


def test_reset_clears_committed_clock():
    ch = _metered()
    ch.transmit(2500)
    ch.reset()
    assert ch.now == 0.0
    assert ch.budget_remaining() == 1000
