"""Sub-quadratic engine invariants: chunked == recurrent, segment chaining,
windowed attention vs full-softmax oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.models.attention import repeat_kv, windowed_attention
from repro.models.linear_attention import (LOG_DECAY_MIN,
                                           chunked_linear_attention,
                                           linear_attention_step,
                                           reference_scan)


def _rand(rng, shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("mode", ["rwkv", "ssm"])
@pytest.mark.parametrize("chunk", [4, 16])
def test_chunked_equals_recurrent(rng, mode, chunk):
    b, s, h, dk, dv = 2, 32, 2, 8, 8
    q, k, v = _rand(rng, (b, s, h, dk)), _rand(rng, (b, s, h, dk)), _rand(rng, (b, s, h, dv))
    ld = -jnp.abs(_rand(rng, (b, s, h, dk if mode == "rwkv" else 1)))
    bonus = _rand(rng, (h, dk)) if mode == "rwkv" else None
    y, st = chunked_linear_attention(q, k, v, ld, bonus=bonus, chunk=chunk,
                                     mode=mode)
    ld_c = jnp.clip(jnp.broadcast_to(ld, (b, s, h, dk)), LOG_DECAY_MIN, -1e-9)
    ry, rst = reference_scan(q, k, v, ld_c, bonus=bonus, mode=mode)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ry), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(rst), atol=1e-4, rtol=1e-4)


@given(seed=st.integers(0, 2**16), split=st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_property_segment_chaining(seed, split):
    """Processing a stream in segments with carried state == one pass
    (the invariant long-context ingestion relies on)."""
    r = np.random.default_rng(seed)
    b, s, h, dk, dv, chunk = 1, 32, 1, 4, 4, 4
    q, k, v = (_rand(r, (b, s, h, dk)), _rand(r, (b, s, h, dk)),
               _rand(r, (b, s, h, dv)))
    ld = -jnp.abs(_rand(r, (b, s, h, dk)))
    y_full, st_full = chunked_linear_attention(q, k, v, ld, chunk=chunk,
                                               mode="ssm")
    m = split * 8
    y1, st1 = chunked_linear_attention(q[:, :m], k[:, :m], v[:, :m], ld[:, :m],
                                       chunk=chunk, mode="ssm")
    y2, st2 = chunked_linear_attention(q[:, m:], k[:, m:], v[:, m:], ld[:, m:],
                                       chunk=chunk, mode="ssm",
                                       initial_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               atol=1e-4, rtol=1e-4)


def test_step_equals_chunked_single_tokens(rng):
    b, s, h, dk, dv = 1, 8, 2, 4, 4
    q, k, v = _rand(rng, (b, s, h, dk)), _rand(rng, (b, s, h, dk)), _rand(rng, (b, s, h, dv))
    ld = jnp.clip(-jnp.abs(_rand(rng, (b, s, h, dk))), LOG_DECAY_MIN, -1e-9)
    u = _rand(rng, (h, dk))
    y_c, _ = chunked_linear_attention(q, k, v, ld, bonus=u, chunk=4, mode="rwkv")
    state = jnp.zeros((b, h, dk, dv), jnp.float32)
    ys = []
    for t in range(s):
        y, state = linear_attention_step(q[:, t], k[:, t], v[:, t], ld[:, t],
                                         state, bonus=u, mode="rwkv")
        ys.append(y)
    y_s = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               atol=1e-4, rtol=1e-4)


def test_windowed_attention_matches_masked_full(rng):
    from repro.kernels.ref import flash_attention_ref
    b, s, h, kh, hd, w = 1, 64, 4, 2, 16, 16
    q = _rand(rng, (b, s, h, hd))
    k = _rand(rng, (b, s, kh, hd))
    v = _rand(rng, (b, s, kh, hd))
    out = windowed_attention(q, k, v, window=w)
    expect = flash_attention_ref(q, repeat_kv(k, h), repeat_kv(v, h),
                                 causal=True, window=w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=2e-5, rtol=2e-5)


def test_rwkv6_block_chunk_chaining(rng):
    from repro.models.rwkv6 import (init_rwkv6_block, init_rwkv6_state,
                                    rwkv6_block, rwkv6_block_chunk)
    d, hd = 32, 8
    p = init_rwkv6_block(jax.random.PRNGKey(0), d, hd, lora_rank=8, d_ff=64)
    x = _rand(rng, (2, 16, d))
    y_full = rwkv6_block(p, x, head_dim=hd, chunk=4)
    st = init_rwkv6_state(2, d, hd)
    y1, st = rwkv6_block_chunk(p, x[:, :8], st, head_dim=hd, chunk=4)
    y2, _ = rwkv6_block_chunk(p, x[:, 8:], st, head_dim=hd, chunk=4)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)


def test_mamba2_block_chunk_chaining(rng):
    from repro.models.mamba2 import (Mamba2State, init_mamba2_block,
                                     init_mamba2_state, mamba2_block,
                                     mamba2_block_chunk)
    d = 32
    kw = dict(state_dim=8, head_dim=8, expand=2)
    p = init_mamba2_block(jax.random.PRNGKey(0), d, conv_width=4, **kw)
    x = _rand(rng, (2, 16, d))
    y_full = mamba2_block(p, x, chunk=4, **kw)
    st = init_mamba2_state(2, d, conv_width=4, **kw)
    y1, st = mamba2_block_chunk(p, x[:, :8], st, chunk=4, **kw)
    y2, _ = mamba2_block_chunk(p, x[:, 8:], st, chunk=4, **kw)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
