"""Multi-tenant scheduler + event-driven gateway: fairness, budget
conservation, starvation freedom, deterministic replay, mixed-tenant
bucket correctness."""
import jax
import numpy as np
import pytest

from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.data.synthetic import shapes_batch_iterator
from repro.models.cnn import init_cnn
from repro.serve import (ChannelConfig, DeficitRoundRobinScheduler,
                         MultiTenantGateway, OperatingPoint, ServingGateway,
                         TenantRequest, TenantSpec, UplinkJob, jain_fairness)


# ---------------------------------------------------------------------------
# DRR scheduler in isolation (pure host code, no jax)
# ---------------------------------------------------------------------------

def _fill(sched, tenant, n, bits, t=0.0):
    for i in range(n):
        sched.enqueue(UplinkJob(tenant=tenant, req_id=i, bits=bits,
                                t_enqueue=t))


def test_scheduler_rejects_bad_configs():
    with pytest.raises(ValueError):
        DeficitRoundRobinScheduler([])
    with pytest.raises(ValueError):
        DeficitRoundRobinScheduler([TenantSpec("a"), TenantSpec("a")])
    with pytest.raises(ValueError):
        TenantSpec("a", weight=0.0)
    s = DeficitRoundRobinScheduler([TenantSpec("a")])
    with pytest.raises(KeyError):
        s.enqueue(UplinkJob(tenant="ghost", req_id=0, bits=10, t_enqueue=0.0))
    with pytest.raises(ValueError):
        s.enqueue(UplinkJob(tenant="a", req_id=0, bits=0, t_enqueue=0.0))


def test_budget_conservation_across_tenants():
    """Sum of granted bits inside any tick window never exceeds the budget."""
    rng = np.random.default_rng(3)
    sched = DeficitRoundRobinScheduler(
        [TenantSpec("a"), TenantSpec("b", weight=3.0), TenantSpec("c")],
        budget_bits_per_tick=10_000, tick_s=1.0)
    for name in ("a", "b", "c"):            # heterogeneous job sizes
        for i in range(40):
            sched.enqueue(UplinkJob(tenant=name, req_id=i,
                                    bits=int(rng.integers(200, 4_000)),
                                    t_enqueue=0.0))
    t = 0.0
    while sched.pending():
        sched.drain(t)
        t = sched.next_tick_time(t)
    assert sched.tick_grants                # something was granted
    for tick, bits in sched.tick_grants.items():
        assert bits <= 10_000, (tick, bits)
    # everything eventually went out
    assert sum(tq.granted_jobs for tq in sched.tenants.values()) == 120


def test_weighted_shares_track_drr_weights():
    """Under saturation, granted-bit shares track the DRR weights."""
    sched = DeficitRoundRobinScheduler(
        [TenantSpec("heavy", weight=3.0), TenantSpec("light", weight=1.0)],
        budget_bits_per_tick=8_000, tick_s=1.0)
    _fill(sched, "heavy", 200, 500)
    _fill(sched, "light", 200, 500)
    for k in range(10):                     # saturated: both always backlogged
        sched.drain(float(k))
    shares = sched.grant_shares()
    assert shares["heavy"] == pytest.approx(0.75, abs=0.1)
    assert shares["light"] == pytest.approx(0.25, abs=0.1)


def test_no_starvation_under_saturated_tenant():
    """A flooding tenant cannot lock a light tenant out of the uplink."""
    sched = DeficitRoundRobinScheduler(
        [TenantSpec("flood"), TenantSpec("light")],
        budget_bits_per_tick=4_000, tick_s=1.0)
    _fill(sched, "flood", 500, 1_000)
    _fill(sched, "light", 3, 1_000)
    granted_at = {}
    for k in range(20):
        for job in sched.drain(float(k)):
            if job.tenant == "light":
                granted_at[job.req_id] = k
        if len(granted_at) == 3:
            break
    assert sorted(granted_at) == [0, 1, 2]
    # equal weights + persistent credit: light's whole queue clears within
    # a few ticks even though flood has 500 jobs pending
    assert max(granted_at.values()) <= 5


def test_oversize_job_spans_ticks_and_conserves_budget():
    sched = DeficitRoundRobinScheduler(
        [TenantSpec("a")], budget_bits_per_tick=1_000, tick_s=1.0)
    sched.enqueue(UplinkJob(tenant="a", req_id=0, bits=2_500, t_enqueue=0.0))
    sched.enqueue(UplinkJob(tenant="a", req_id=1, bits=800, t_enqueue=0.0))
    t, granted = 0.0, []
    for _ in range(8):
        granted += sched.drain(t)
        t = sched.next_tick_time(t)
        if not sched.pending():
            break
    assert [j.req_id for j in granted] == [0, 1]
    for tick, bits in sched.tick_grants.items():
        assert bits <= 1_000, (tick, bits)
    # the oversize job charged 2.5 ticks of budget before the small one fit
    assert sum(sched.tick_grants.values()) == 2_500 + 800


def test_drain_is_deterministic():
    def run():
        sched = DeficitRoundRobinScheduler(
            [TenantSpec("a"), TenantSpec("b", weight=2.0)],
            budget_bits_per_tick=3_000, tick_s=1.0)
        _fill(sched, "a", 30, 700)
        _fill(sched, "b", 30, 900)
        log = []
        t = 0.0
        while sched.pending():
            log += [(j.tenant, j.req_id) for j in sched.drain(t)]
            t = sched.next_tick_time(t)
        return log
    assert run() == run()


# ---------------------------------------------------------------------------
# Event-driven gateway end to end (tiny system)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_system():
    cnn_cfg = smoke_config()._replace(input_size=32)
    data_cfg = smoke_data_config()._replace(image_size=32, batch_size=8)
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    bank = {}
    for c in (4, 8):
        baf = init_baf_conv(jax.random.PRNGKey(c),
                            BaFConvConfig(c=c, q=cnn_cfg.split_q, hidden=8))
        bank[c] = (baf, np.arange(c))
    imgs, _ = next(shapes_batch_iterator(data_cfg, seed=5))
    return params, bank, np.asarray(imgs)


def _mt_gateway(params, bank, **kw):
    args = dict(
        tenants=[TenantSpec("a"), TenantSpec("b")],
        channel_cfg=ChannelConfig(bandwidth_bps=1e6, base_latency_s=0.005),
        default_op=OperatingPoint(c=8, bits=8),
        budget_bits_per_tick=100_000, tick_s=0.05,
        max_batch=4, batch_window_s=0.02)
    args.update(kw)
    return MultiTenantGateway(params, bank, **args)


def _workload(imgs, tenants=("a", "b"), n=8, dt=0.002):
    return [TenantRequest(tenant=tenants[i % len(tenants)], img=imgs[i],
                          t_submit=dt * i) for i in range(n)]


def test_gateway_serves_all_tenants_in_order(tiny_system):
    params, bank, imgs = tiny_system
    gw = _mt_gateway(params, bank)
    resp, tel = gw.serve_tenants(_workload(imgs))
    assert {k: len(v) for k, v in resp.items()} == {"a": 4, "b": 4}
    for t, rs in resp.items():
        assert [r.req_id for r in rs] == list(range(len(rs)))
        assert all(np.isfinite(r.logits).all() for r in rs)
    assert len(tel) == 8 and set(tel.tenants()) == {"a", "b"}
    assert tel.fairness("bits_on_wire") == pytest.approx(1.0, abs=0.05)


def test_gateway_budget_conserved_per_tick(tiny_system):
    params, bank, imgs = tiny_system
    gw = _mt_gateway(params, bank, budget_bits_per_tick=2_000, tick_s=0.05)
    gw.serve_tenants(_workload(imgs))
    sched = gw.last_scheduler
    assert sched.tick_grants
    for tick, bits in sched.tick_grants.items():
        assert bits <= 2_000, (tick, bits)


def test_gateway_deterministic_replay(tiny_system):
    params, bank, imgs = tiny_system
    gw = _mt_gateway(params, bank)
    work = _workload(imgs)
    r1, t1 = gw.serve_tenants(work)
    r2, t2 = gw.serve_tenants(work)
    for tenant in r1:
        for a, b in zip(r1[tenant], r2[tenant]):
            assert np.array_equal(a.logits, b.logits)
            assert a.op == b.op and a.stats.total_bits == b.stats.total_bits
    virt = lambda tel: [(r.tenant, r.req_id, r.bits_on_wire, r.sched_wait_s,
                         r.wire_latency_s, r.batch_size) for r in tel.records]
    assert virt(t1) == virt(t2)


def test_mixed_tenant_bucket_bit_exact_vs_single_tenant(tiny_system):
    """Batching tenant A's requests together with tenant B's (same bucket
    key) must not change A's logits at all — restore is row-independent."""
    params, bank, imgs = tiny_system
    op = OperatingPoint(c=8, bits=8)
    mixed = _mt_gateway(params, bank, max_batch=4)
    # a0, b0, a1, b1 -> one full (8,8) bucket holding both tenants
    work = [TenantRequest(tenant=("a", "b")[i % 2], img=imgs[i % 2],
                          t_submit=0.0) for i in range(4)]
    r_mixed, tel = mixed.serve_tenants(work)
    assert max(r.batch_size for r in tel.records) == 4   # really mixed
    solo = ServingGateway(params, bank, default_op=op, max_batch=4)
    r_solo, _ = solo.serve(np.stack([imgs[0], imgs[1], imgs[0], imgs[1]]))
    np.testing.assert_array_equal(r_mixed["a"][0].logits, r_solo[0].logits)
    np.testing.assert_array_equal(r_mixed["b"][0].logits, r_solo[1].logits)
    np.testing.assert_array_equal(r_mixed["a"][1].logits, r_solo[2].logits)
    np.testing.assert_array_equal(r_mixed["b"][1].logits, r_solo[3].logits)


def test_light_tenant_not_starved_end_to_end(tiny_system):
    """One tenant floods the uplink; the light tenant still completes with
    bounded scheduler wait."""
    params, bank, imgs = tiny_system
    gw = _mt_gateway(params, bank, budget_bits_per_tick=4_000, tick_s=0.05,
                     batch_window_s=0.01)
    work = [TenantRequest(tenant="a", img=imgs[i % 8], t_submit=0.0)
            for i in range(12)]
    work += [TenantRequest(tenant="b", img=imgs[0], t_submit=0.0),
             TenantRequest(tenant="b", img=imgs[1], t_submit=0.01)]
    resp, tel = gw.serve_tenants(work)
    assert len(resp["a"]) == 12 and len(resp["b"]) == 2
    waits = {t: tel.percentile("sched_wait_s", 99, tenant=t)
             for t in ("a", "b")}
    # equal weights: the light tenant waits no longer than the flooder
    assert waits["b"] <= waits["a"] + 1e-9


def test_jain_fairness_index():
    assert jain_fairness([1, 1, 1, 1]) == pytest.approx(1.0)
    assert jain_fairness([1, 0, 0, 0]) == pytest.approx(0.25)
    assert jain_fairness([]) == 1.0
