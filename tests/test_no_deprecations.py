"""The deprecation ratchet: in-repo production flows must not route through
deprecated entry points.

The one-release shims (``encode_activation`` / ``decode_stream``) completed
their deprecation cycle and are now removed (docs/MIGRATION.md); this module
pins both halves of that promise: the representative end-to-end flows emit
no repo-owned DeprecationWarnings (so a future shim cannot silently creep
back into production paths), and the removed names really are gone.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.core.split import SplitInferenceEngine
from repro.data.synthetic import shapes_batch_iterator
from repro.models.cnn import init_cnn
from repro.serve import (ChannelConfig, MultiTenantGateway, OperatingPoint,
                         RateController, ServingGateway, SimulatedChannel,
                         TenantRequest, TenantSpec, build_rd_table)


@pytest.fixture(scope="module")
def tiny_system():
    cnn_cfg = smoke_config()._replace(input_size=32)
    data_cfg = smoke_data_config()._replace(image_size=32, batch_size=4)
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    bank = {}
    for c in (4, 8):
        baf = init_baf_conv(jax.random.PRNGKey(c),
                            BaFConvConfig(c=c, q=cnn_cfg.split_q, hidden=8))
        bank[c] = (baf, np.arange(c))
    imgs, _ = next(shapes_batch_iterator(data_cfg, seed=11))
    return params, bank, np.asarray(imgs)


def _shim_deprecations(records):
    """DeprecationWarnings raised by this repo's own shims (their messages
    point at repro.pipeline); third-party deprecations are not ours to fix
    here and are ignored."""
    return [w for w in records
            if issubclass(w.category, DeprecationWarning)
            and "repro.pipeline" in str(w.message)]


def test_in_repo_serving_flows_emit_no_deprecation_warnings(tiny_system):
    params, bank, imgs = tiny_system
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        # single-operating-point engine, end to end
        eng = SplitInferenceEngine(params, bank[8][0], np.arange(8), bits=6)
        eng(imgs[:2])
        # single-tenant gateway with a channel + controller
        table = build_rd_table(params, bank, imgs[:2], bits_sweep=(4, 8))
        gw = ServingGateway(
            params, bank,
            controller=RateController(table, quality_floor_db=0.0),
            channel=SimulatedChannel(ChannelConfig(bandwidth_bps=20e6)),
            max_batch=4)
        gw.serve(imgs)
        # multi-tenant event loop with rans wire accounting
        mt = MultiTenantGateway(
            params, bank, tenants=[TenantSpec("a"), TenantSpec("b")],
            default_op=OperatingPoint(c=8, bits=8), backend="rans",
            max_batch=4, batch_window_s=0.01, adaptive_window=True)
        mt.serve_tenants([
            TenantRequest("ab"[i % 2], imgs[i % len(imgs)], 0.001 * i)
            for i in range(6)])
    bad = _shim_deprecations(rec)
    assert not bad, (
        "in-repo flow still routes through deprecated entry points:\n"
        + "\n".join(f"{w.filename}:{w.lineno}: {w.message}" for w in bad))


def test_ratchet_filter_catches_repo_style_warnings():
    """Canary for the filter above: a repo-style deprecation (message
    pointing at repro.pipeline, as this repo's shims always did) must be
    caught, or the ratchet is silently blind. Any future shim MUST follow
    the same message convention for the ratchet to see it."""
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        warnings.warn("synthetic_shim is deprecated; use the "
                      "repro.pipeline plan API", DeprecationWarning)
        warnings.warn("unrelated third-party thing", DeprecationWarning)
    caught = _shim_deprecations(rec)
    assert len(caught) == 1
    assert "synthetic_shim" in str(caught[0].message)


def test_removed_shims_are_gone():
    """The one-release deprecation window closed: the loose-tuple entry
    points must no longer exist anywhere importable."""
    import repro.core.split as split
    for name in ("encode_activation", "decode_stream", "_decode_stream"):
        assert not hasattr(split, name), (
            f"core.split.{name} was promised removed after its one-release "
            f"deprecation window (docs/MIGRATION.md)")
