"""Cloud executor + admission control: work conservation, no silent drops,
shed-priority ordering, deterministic replay, and the shed-telemetry split.

Property tests run under hypothesis when installed (requirements-dev.txt);
seeded deterministic sweeps cover the same invariants on bare environments.
"""
import jax
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.data.synthetic import shapes_batch_iterator
from repro.models.cnn import init_cnn
from repro.serve import (AlwaysAdmit, ChannelConfig, CompositeAdmission,
                         LinearCostModel, MeasuredCost, MicroBatch,
                         MultiQueueExecutor, MultiTenantGateway,
                         OperatingPoint, QueueDepthAdmission, RequestShed,
                         SerialExecutor, ShedRecord, Telemetry,
                         TenantRequest, TenantSpec, TokenBucketAdmission,
                         priority_depth_limits)
from repro.serve.telemetry import RequestRecord


def _batch(n=4, key="k"):
    return MicroBatch(key=key, requests=[None] * n, target=n)


def _bind(ex, compute_s=0.003):
    ex.run_fn = lambda batch: (np.zeros((batch.padded_size, 4)), compute_s)
    return ex


# ---------------------------------------------------------------------------
# Executor mechanics
# ---------------------------------------------------------------------------

def test_linear_cost_model_is_deterministic():
    cm = LinearCostModel(base_s=0.01, per_item_s=0.002)
    assert cm.duration_s(_batch(4), measured_s=123.0) == pytest.approx(0.018)
    assert MeasuredCost().duration_s(_batch(4), 0.7) == 0.7


def test_serial_executor_serializes_on_the_virtual_clock():
    ex = _bind(SerialExecutor(cost=LinearCostModel(0.01, 0.0)))
    a = ex.submit(_batch(), 0.0)
    b = ex.submit(_batch(), 0.0)          # ready at 0 but the queue is busy
    c = ex.submit(_batch(), 0.5)          # ready after the queue went idle
    assert (a.t_start, a.t_done) == (0.0, pytest.approx(0.01))
    assert b.t_start == pytest.approx(a.t_done)
    assert c.t_start == 0.5
    assert ex.capacity == 1


def test_multi_queue_runs_batches_in_parallel():
    ex = _bind(MultiQueueExecutor(4, cost=LinearCostModel(0.01, 0.0)))
    tickets = [ex.submit(_batch(), 0.0) for _ in range(4)]
    assert all(t.t_start == 0.0 for t in tickets)          # all queues free
    assert len({t.queue for t in tickets}) == 4
    fifth = ex.submit(_batch(), 0.0)
    assert fifth.t_start == pytest.approx(0.01)            # earliest finish


def test_per_queue_service_rates_scale_durations():
    ex = _bind(MultiQueueExecutor(2, rates=[1.0, 2.0],
                                  cost=LinearCostModel(0.01, 0.0)))
    # the fast queue (rate 2 -> 5 ms) finishes first, so it wins the pick
    t = ex.submit(_batch(), 0.0)
    assert t.queue == 1
    assert t.service_s == pytest.approx(0.005)


def test_bucket_affinity_breaks_finish_time_ties():
    ex = _bind(MultiQueueExecutor(3, cost=LinearCostModel(0.01, 0.0)))
    a = ex.submit(_batch(key="x"), 0.0)
    for t in (a, *[ex.submit(_batch(key="y"), 0.0) for _ in range(2)]):
        ex.on_start(t)
        ex.complete(t)
    # all queues idle again and tie on finish time: "x" goes back to the
    # queue that last served it
    b = ex.submit(_batch(key="x"), 1.0)
    assert b.queue == a.queue


def test_poll_returns_completion_order():
    ex = _bind(MultiQueueExecutor(2, rates=[1.0, 4.0],
                                  cost=LinearCostModel(0.01, 0.0)))
    slow = ex.submit(_batch(), 0.0)        # fast queue wins the first pick
    ex.submit(_batch(), 0.0)               # second lands on the slow queue
    fast, slow = sorted(ex.history, key=lambda t: t.t_done)
    assert fast.t_done < slow.t_done
    # virtual completion order, not submission order — matches exec_done
    assert [t.seq for t in ex.poll(1.0)] == [fast.seq, slow.seq]


def test_depth_tracking_and_poll_drain():
    ex = _bind(MultiQueueExecutor(2, cost=LinearCostModel(0.01, 0.0)))
    t1 = ex.submit(_batch(), 0.0)
    t2 = ex.submit(_batch(), 0.0)
    t3 = ex.submit(_batch(), 0.0)
    assert ex.depth() == 3 and ex.max_depth_seen == 3
    assert sum(ex.queue_depths()) == 3
    done_now = ex.poll(0.01)
    assert {t.seq for t in done_now} == {t1.seq, t2.seq}
    for t in (t1, t2):
        ex.on_start(t)
        ex.complete(t)
    assert ex.depth() == 1
    assert [t.seq for t in ex.drain()] == [t3.seq]
    with pytest.raises(RuntimeError):
        ex.complete(t1)                   # double completion is a bug
    ex.reset()
    assert ex.depth() == 0 and ex.history == []


def test_executor_rejects_bad_configs():
    with pytest.raises(ValueError):
        MultiQueueExecutor(0)
    with pytest.raises(ValueError):
        MultiQueueExecutor(2, rates=[1.0])
    with pytest.raises(ValueError):
        MultiQueueExecutor(2, rates=[1.0, -1.0])
    ex = MultiQueueExecutor(2)
    with pytest.raises(RuntimeError, match="run_fn"):
        ex.submit(_batch(), 0.0)


def _work_conserving_replay(ex, submissions):
    """Re-derive every ticket's queue choice from the executor's stated
    rule; any divergence breaks work conservation or determinism."""
    busy = [0.0] * ex.capacity
    rates = [q.rate for q in ex._queues]
    last_key = [None] * ex.capacity
    for (t_ready, size, key), ticket in zip(submissions, ex.history):
        best = None
        for i in range(ex.capacity):
            start = max(t_ready, busy[i])
            done = start + ticket.service_s * rates[ticket.queue] / rates[i]
            affinity = 0 if last_key[i] == key else 1
            rank = (done, affinity, i)
            if best is None or rank < best[0]:
                best = (rank, i, start)
        _, i, start = best
        assert ticket.queue == i, (ticket.seq, ticket.queue, i)
        assert ticket.t_start == pytest.approx(start)
        busy[i] = ticket.t_done
        last_key[i] = key


@settings(max_examples=60, deadline=None)
@given(plan=(st.lists(st.tuples(st.floats(0.0, 2.0), st.integers(1, 8)),
                      min_size=1, max_size=30)
             if HAVE_HYPOTHESIS else None),
       n_queues=st.integers(1, 5) if HAVE_HYPOTHESIS else None)
def test_work_conservation_property(plan, n_queues):
    """A batch starts at max(ready, earliest-finishing queue): no queue
    sits idle while ready work waits, for any workload."""
    ex = _bind(MultiQueueExecutor(n_queues,
                                  cost=LinearCostModel(0.004, 0.001)))
    subs = []
    t = 0.0
    for dt, size in plan:
        t += dt
        key = f"k{size}"
        ex.submit(_batch(size, key=key), t)
        subs.append((t, size, key))
    _work_conserving_replay(ex, subs)


def test_work_conservation_seeded(rng):
    """The same invariant on 50 seeded random workloads (no hypothesis)."""
    for trial in range(50):
        n_queues = int(rng.integers(1, 6))
        rates = [float(r) for r in rng.uniform(0.5, 2.0, size=n_queues)]
        ex = _bind(MultiQueueExecutor(n_queues, rates=rates,
                                      cost=LinearCostModel(0.004, 0.001)))
        subs, t = [], 0.0
        for _ in range(int(rng.integers(1, 40))):
            t += float(rng.uniform(0, 0.05))
            size = int(rng.integers(1, 9))
            key = f"k{int(rng.integers(0, 3))}"
            ex.submit(_batch(size, key=key), t)
            subs.append((t, size, key))
        _work_conserving_replay(ex, subs)


def test_multi_queue_beats_serial_makespan():
    """4 queues under deep backlog finish ~4x sooner on the virtual clock."""
    cost = LinearCostModel(0.01, 0.0)
    serial = _bind(SerialExecutor(cost=cost))
    multi = _bind(MultiQueueExecutor(4, cost=cost))
    for ex in (serial, multi):
        for _ in range(32):
            ex.submit(_batch(), 0.0)
    span = lambda ex: max(t.t_done for t in ex.history)  # noqa: E731
    assert span(serial) == pytest.approx(0.32)
    assert span(multi) == pytest.approx(0.08)
    assert span(serial) / span(multi) >= 3.9


# ---------------------------------------------------------------------------
# Admission policies
# ---------------------------------------------------------------------------

def test_token_bucket_caps_sustained_rate():
    pol = TokenBucketAdmission(rate_per_s=10.0, burst=3.0)
    ex = _bind(SerialExecutor())
    admitted = sum(
        pol.admit(tenant="a", priority=0, t=i * 0.01, executor=ex).admitted
        for i in range(100))                             # 1 s of 100 req/s
    # burst (3) + ~1 s of refill (10): the flood is clipped to the bucket
    assert 12 <= admitted <= 14
    d = pol.admit(tenant="a", priority=0, t=0.991, executor=ex)
    assert not d.admitted and "token-bucket" in d.reason
    # an independent tenant has its own bucket
    assert pol.admit(tenant="b", priority=0, t=0.991, executor=ex).admitted


def test_token_bucket_per_tenant_override_and_reset():
    pol = TokenBucketAdmission(1.0, 1.0, per_tenant={"gold": (100.0, 10.0)})
    ex = _bind(SerialExecutor())
    assert sum(pol.admit(tenant="gold", priority=0, t=0.0,
                         executor=ex).admitted for _ in range(10)) == 10
    assert sum(pol.admit(tenant="be", priority=0, t=0.0,
                         executor=ex).admitted for _ in range(10)) == 1
    pol.reset()
    assert pol.admit(tenant="be", priority=0, t=0.0, executor=ex).admitted


def test_queue_depth_admission_sheds_at_limit():
    ex = _bind(MultiQueueExecutor(2, cost=LinearCostModel(0.01, 0.0)))
    pol = QueueDepthAdmission(max_depth=2, per_priority={1: 4})
    for _ in range(2):
        ex.submit(_batch(), 0.0)
    low = pol.admit(tenant="a", priority=0, t=0.0, executor=ex)
    high = pol.admit(tenant="a", priority=1, t=0.0, executor=ex)
    assert not low.admitted and "queue-depth" in low.reason
    assert high.admitted                   # premium rides the deeper limit


@settings(max_examples=100, deadline=None)
@given(depth=st.integers(0, 30) if HAVE_HYPOTHESIS else None,
       base=st.integers(1, 8) if HAVE_HYPOTHESIS else None,
       headroom=st.integers(0, 6) if HAVE_HYPOTHESIS else None,
       p_lo=st.integers(0, 3) if HAVE_HYPOTHESIS else None,
       p_hi=st.integers(0, 3) if HAVE_HYPOTHESIS else None)
def test_shed_priority_ordering_property(depth, base, headroom, p_lo, p_hi):
    """With monotone per-priority limits, admission is monotone in
    priority: a shed premium request implies every best-effort request at
    the same backlog is shed too."""
    p_lo, p_hi = min(p_lo, p_hi), max(p_lo, p_hi)
    pol = QueueDepthAdmission(
        base, per_priority=priority_depth_limits(base, range(4),
                                                 headroom=headroom))
    ex = _bind(MultiQueueExecutor(1, cost=LinearCostModel(1.0, 0.0)))
    for _ in range(depth):
        ex.submit(_batch(), 0.0)
    lo = pol.admit(tenant="x", priority=p_lo, t=0.0, executor=ex).admitted
    hi = pol.admit(tenant="x", priority=p_hi, t=0.0, executor=ex).admitted
    assert hi or not lo                    # admitted(hi) >= admitted(lo)


def test_shed_priority_ordering_seeded(rng):
    for _ in range(100):
        base = int(rng.integers(1, 9))
        headroom = int(rng.integers(0, 7))
        depth = int(rng.integers(0, 31))
        pol = QueueDepthAdmission(
            base, per_priority=priority_depth_limits(base, range(4),
                                                     headroom=headroom))
        ex = _bind(MultiQueueExecutor(1, cost=LinearCostModel(1.0, 0.0)))
        for _ in range(depth):
            ex.submit(_batch(), 0.0)
        decisions = [pol.admit(tenant="x", priority=p, t=0.0,
                               executor=ex).admitted for p in range(4)]
        # once a priority is admitted, every higher one is too
        assert decisions == sorted(decisions)


def test_composite_admission_short_circuits():
    bucket = TokenBucketAdmission(1.0, 1.0)
    pol = CompositeAdmission([QueueDepthAdmission(1), bucket])
    ex = _bind(MultiQueueExecutor(1, cost=LinearCostModel(1.0, 0.0)))
    ex.submit(_batch(), 0.0)               # backlog hits the depth limit
    d = pol.admit(tenant="a", priority=0, t=0.0, executor=ex)
    assert not d.admitted and "queue-depth" in d.reason
    # the depth rejection must not have spent the tenant's token
    assert bucket._state.get("a") is None
    assert AlwaysAdmit().admit(tenant="a", priority=0, t=0.0,
                               executor=ex).admitted


# ---------------------------------------------------------------------------
# Telemetry: shed is its own series (regression for latency pollution)
# ---------------------------------------------------------------------------

def _rec(req_id, latency):
    return RequestRecord(req_id=req_id, c=8, bits=8, bits_on_wire=1000,
                         wire_latency_s=latency, queue_wait_s=0.0,
                         compute_s=0.0, batch_size=1, padded_size=1,
                         tenant="a")


def test_shed_records_never_pollute_latency_percentiles():
    served = Telemetry()
    mixed = Telemetry()
    for i in range(20):
        served.record(_rec(i, 0.010 + i * 1e-4))
        mixed.record(_rec(i, 0.010 + i * 1e-4))
    for i in range(20):                    # a flood of rejections
        mixed.record_shed(ShedRecord(req_id=100 + i, tenant="a",
                                     t_submit=0.0, reason="token-bucket"))
    for p in (50, 99):
        assert (mixed.percentile("total_latency_s", p)
                == served.percentile("total_latency_s", p))
    s = mixed.summary()
    assert s["count"] == 20 and s["shed"] == 20
    assert s["shed_rate"] == pytest.approx(0.5)
    assert s["shed_by_tenant"] == {"a": 20}
    assert "shed" not in served.summary()


def test_shed_only_tenant_still_reported():
    tel = Telemetry()
    tel.record(_rec(0, 0.01))
    tel.record_shed(ShedRecord(req_id=0, tenant="ghost", t_submit=0.0,
                               reason="queue-depth 9>=8"))
    per = tel.per_tenant()
    assert per["ghost"]["count"] == 0 and per["ghost"]["shed"] == 1
    assert per["a"]["count"] == 1 and per["a"]["shed"] == 0
    # one row schema for every tenant: shed-only rows carry the same keys
    # (latency fields None) so consumers never hit a KeyError
    assert per["ghost"].keys() == per["a"].keys()
    assert per["ghost"]["p99_latency_s"] is None
    assert per["a"]["p99_latency_s"] is not None


# ---------------------------------------------------------------------------
# Gateway integration: no silent drops + bit-identical replay
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_bank():
    cnn_cfg = smoke_config()._replace(input_size=32)
    data_cfg = smoke_data_config()._replace(image_size=32, batch_size=8)
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    baf = init_baf_conv(jax.random.PRNGKey(8),
                        BaFConvConfig(c=8, q=cnn_cfg.split_q, hidden=8))
    imgs, _ = next(shapes_batch_iterator(data_cfg, seed=5))
    return params, {8: (baf, np.arange(8))}, np.asarray(imgs)


def _overload_gateway(params, bank, *, executor, admission):
    return MultiTenantGateway(
        params, bank,
        tenants=[TenantSpec("gold", priority=1), TenantSpec("be")],
        channel_cfg=ChannelConfig(bandwidth_bps=50e6, base_latency_s=0.001),
        default_op=OperatingPoint(c=8, bits=8), max_batch=2,
        tick_s=0.01, batch_window_s=0.002,
        executor=executor, admission=admission)


def _burst(imgs, n, dt=0.0004):
    return [TenantRequest(("gold", "be")[i % 2], imgs[i % len(imgs)],
                          t_submit=dt * i) for i in range(n)]


def test_gateway_sheds_explicitly_and_drops_nothing(tiny_bank):
    params, bank, imgs = tiny_bank
    gw = _overload_gateway(
        params, bank,
        executor=MultiQueueExecutor(2, cost=LinearCostModel(0.02, 0.01)),
        admission=QueueDepthAdmission(
            1, per_priority=priority_depth_limits(1, [0, 1], headroom=2)))
    work = _burst(imgs, 16)
    out, tel = gw.serve_tenants(work)
    # every submission ended exactly once: response or explicit shed
    for name, n_offered in (("gold", 8), ("be", 8)):
        assert len(out[name]) == n_offered
    served = sum(not isinstance(r, RequestShed)
                 for rs in out.values() for r in rs)
    shed = [r for rs in out.values() for r in rs if isinstance(r, RequestShed)]
    assert served + len(shed) == len(work)
    assert shed, "this burst must overload the depth limit"
    assert len(tel) == served and len(tel.shed) == len(shed)
    for s in shed:
        assert "queue-depth" in s.reason     # explicit, reasoned outcomes
    # the brown-out is priority-ordered: best effort sheds at least as much
    by_tenant = tel.shed_by_tenant()
    assert by_tenant.get("be", 0) >= by_tenant.get("gold", 0)
    # shed requests contributed zero wire bits (never encoded)
    assert all(r.bits_on_wire > 0 for r in tel.records)


def test_gateway_replay_is_bit_identical_with_deterministic_cost(tiny_bank):
    params, bank, imgs = tiny_bank
    runs = []
    for _ in range(2):
        gw = _overload_gateway(
            params, bank,
            executor=MultiQueueExecutor(2, cost=LinearCostModel(0.01, 0.002)),
            admission=CompositeAdmission([
                TokenBucketAdmission(2000.0, 4.0),
                QueueDepthAdmission(2, per_priority={1: 6}),
            ]))
        out, tel = gw.serve_tenants(_burst(imgs, 12))
        runs.append((out, tel))
    (out_a, tel_a), (out_b, tel_b) = runs
    assert tel_a.records == tel_b.records        # frozen dataclass equality
    assert tel_a.shed == tel_b.shed
    for name in out_a:
        for x, y in zip(out_a[name], out_b[name]):
            assert isinstance(x, RequestShed) == isinstance(y, RequestShed)
            if not isinstance(x, RequestShed):
                np.testing.assert_array_equal(x.logits, y.logits)


def test_gateway_multi_queue_matches_serial_logits(tiny_bank):
    """The executor is a scheduling model: it must never change results."""
    params, bank, imgs = tiny_bank
    cost = LinearCostModel(0.01, 0.002)
    r_serial, _ = _overload_gateway(
        params, bank, executor=SerialExecutor(cost=cost),
        admission=None).serve_tenants(_burst(imgs, 8))
    r_multi, _ = _overload_gateway(
        params, bank, executor=MultiQueueExecutor(4, cost=cost),
        admission=None).serve_tenants(_burst(imgs, 8))
    for name in r_serial:
        for a, b in zip(r_serial[name], r_multi[name]):
            np.testing.assert_allclose(a.logits, b.logits,
                                       atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Priority-aware queue selection (TenantSpec.priority on the batch)
# ---------------------------------------------------------------------------

def _pbatch(n=4, key="k", priority=0):
    from types import SimpleNamespace
    reqs = [SimpleNamespace(priority=priority, tenant="")] * n
    return MicroBatch(key=key, requests=reqs, target=n)


def test_priority_tie_break_prefers_the_matching_queue():
    """Two queues tie on finish time and neither holds bucket affinity: the
    one that last served this priority class wins, even when it has the
    higher index (pre-priority ordering would pick queue 0)."""
    ex = _bind(MultiQueueExecutor(2, cost=LinearCostModel(0.01, 0.0)))
    a = ex.submit(_pbatch(key="a", priority=0), 0.0)     # q0, best-effort
    b = ex.submit(_pbatch(key="b", priority=1), 0.0)     # q1, premium
    assert (a.queue, b.queue) == (0, 1)
    for t in (a, b):
        ex.on_start(t)
        ex.complete(t)
    # both queues idle, equal finish times, no bucket match for key "c":
    # the premium batch follows its class onto q1
    c = ex.submit(_pbatch(key="c", priority=1), 1.0)
    assert c.queue == 1
    assert c.priority == 1
    # and best-effort traffic stays off the premium queue
    d = ex.submit(_pbatch(key="d", priority=0), 2.0)
    assert d.queue == 0


def test_bucket_affinity_still_outranks_priority_affinity():
    ex = _bind(MultiQueueExecutor(2, cost=LinearCostModel(0.01, 0.0)))
    a = ex.submit(_pbatch(key="x", priority=0), 0.0)     # q0 serves bucket x
    b = ex.submit(_pbatch(key="y", priority=1), 0.0)     # q1 premium
    for t in (a, b):
        ex.on_start(t)
        ex.complete(t)
    # a premium batch of bucket x: plan/trace affinity beats class affinity
    c = ex.submit(_pbatch(key="x", priority=1), 1.0)
    assert c.queue == a.queue == 0


def test_equal_priority_selection_is_bit_identical_to_legacy_order():
    """Regression gate for the scheduling change: when every batch shares
    one priority class, queue picks must match the pre-priority
    (finish-time, bucket-affinity, index) rank exactly — replayed against a
    reference reimplementation over a seeded random workload."""
    rng = np.random.default_rng(42)
    ex = _bind(MultiQueueExecutor(3, rates=[1.0, 2.0, 1.5],
                                  cost=LinearCostModel(0.01, 0.002)))
    # reference: the old selection over mirrored queue state
    busy = [0.0, 0.0, 0.0]
    rates = [1.0, 2.0, 1.5]
    last_key = [None, None, None]
    inflight = []
    for step in range(60):
        n = int(rng.integers(1, 5))
        key = f"k{int(rng.integers(0, 4))}"
        t_ready = float(rng.uniform(0.0, 0.5)) + step * 0.002
        batch = _pbatch(n=n, key=key, priority=3)     # one shared class
        duration = 0.01 + 0.002 * n
        best = None
        for i in range(3):
            start = max(t_ready, busy[i])
            dur = duration / rates[i]
            rank = (start + dur, 0 if last_key[i] == key else 1, i)
            if best is None or rank < best[0]:
                best = (rank, i, start, dur)
        _, want_q, want_start, want_dur = best
        busy[want_q] = want_start + want_dur
        last_key[want_q] = key
        ticket = ex.submit(batch, t_ready)
        assert ticket.queue == want_q, f"step {step}"
        assert ticket.t_start == want_start
        assert ticket.service_s == want_dur
        inflight.append(ticket)
        if len(inflight) > 4:               # churn completions like a run
            t = inflight.pop(0)
            ex.on_start(t)
            ex.complete(t)


def test_gateway_wires_tenant_priority_onto_batches(tiny_bank):
    """TenantSpec.priority reaches the executor: served tickets carry the
    priority of the tenants aboard (max over the micro-batch)."""
    params, bank, imgs = tiny_bank
    gw = _overload_gateway(
        params, bank,
        executor=MultiQueueExecutor(2, cost=LinearCostModel(0.01, 0.002)),
        admission=None)
    out, tel = gw.serve_tenants(_burst(imgs, 12))
    prios = {t.priority for t in gw.executor.history}
    # gold (priority 1) traffic flowed, so some batch rode at class 1; the
    # max-batch=2 alternating burst mixes tenants, so class 1 dominates
    assert 1 in prios and prios <= {0, 1}
