"""End-to-end split-inference engine (paper Fig. 1): edge -> wire -> cloud."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.core.split import SplitInferenceEngine
from repro.data.synthetic import shapes_batch_iterator
from repro.models.cnn import cnn_forward, init_cnn


@pytest.fixture(scope="module")
def tiny_system():
    cnn_cfg = smoke_config()._replace(input_size=32)
    data_cfg = smoke_data_config()._replace(image_size=32, batch_size=4)
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    c = 8
    baf = init_baf_conv(jax.random.PRNGKey(1),
                        BaFConvConfig(c=c, q=cnn_cfg.split_q, hidden=8))
    sel = np.arange(c)
    img, _ = next(shapes_batch_iterator(data_cfg, seed=5))
    return cnn_cfg, params, baf, sel, img


def test_engine_end_to_end(tiny_system):
    _, params, baf, sel, img = tiny_system
    eng = SplitInferenceEngine(params, baf, sel, bits=8)
    logits, stats = eng(img)
    assert logits.shape == (img.shape[0], 8)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # accounting invariants
    assert stats.total_bits == stats.payload_bits + stats.side_info_bits
    assert stats.side_info_bits == img.shape[0] * len(sel) * 32  # C*32/example
    assert stats.reduction_vs_raw > 0.9   # 8/256ths of channels @8bit vs fp32


def test_wire_roundtrip_is_exact(tiny_system):
    """Codes that leave encode() arrive bit-identical after to/from_bytes."""
    _, params, baf, sel, img = tiny_system
    eng = SplitInferenceEngine(params, baf, sel, bits=8)
    blob, _ = eng.encode(img)
    from repro.core import codec as wire
    enc = blob.to_tensor()                     # parses blob.data (validates)
    enc2 = wire.EncodedTensor.from_bytes(enc.to_bytes())
    c1, q1 = wire.decode(enc)
    c2, q2 = wire.decode(enc2)
    np.testing.assert_array_equal(c1, c2)
    np.testing.assert_array_equal(np.asarray(q1.mins), np.asarray(q2.mins))


def test_more_bits_means_more_payload(tiny_system):
    _, params, baf, sel, img = tiny_system
    bits_sizes = []
    for n in (2, 4, 8):
        eng = SplitInferenceEngine(params, baf, sel, bits=n)
        _, stats = eng.encode(img)
        bits_sizes.append(stats.payload_bits)
    assert bits_sizes[0] < bits_sizes[1] < bits_sizes[2]


def test_consolidation_flag_changes_output(tiny_system):
    _, params, baf, sel, img = tiny_system
    on = SplitInferenceEngine(params, baf, sel, bits=4, consolidation=True)
    off = SplitInferenceEngine(params, baf, sel, bits=4, consolidation=False)
    lo, _ = on(img)
    lf, _ = off(img)
    assert not np.allclose(np.asarray(lo), np.asarray(lf))


def test_trained_system_tracks_cloud_only_accuracy():
    """Tier-A integration: pretrain tiny CNN, select channels, train BaF a bit;
    split-inference logits should correlate with the unsplit model's."""
    from repro.train.baf_trainer import (compute_channel_order, pretrain_cnn,
                                         train_baf)
    cnn_cfg = smoke_config()._replace(input_size=32)
    data_cfg = smoke_data_config()._replace(image_size=32, batch_size=8)
    params, _ = pretrain_cnn(cnn_cfg, data_cfg, steps=60, verbose=False)
    order = compute_channel_order(params, data_cfg, batches=4).order
    c = 16
    res = train_baf(params, cnn_cfg, data_cfg, order[:c], bits=8, hidden=16,
                    steps=120, verbose=False)
    eng = SplitInferenceEngine(params, res.baf_params, res.sel_idx, bits=8)
    img, labels = next(shapes_batch_iterator(data_cfg, seed=777))
    split_logits, stats = eng(img)
    cloud_logits = cnn_forward(params, img)
    # agreement between split and cloud-only predictions
    agree = float(jnp.mean(jnp.argmax(split_logits, -1)
                           == jnp.argmax(cloud_logits, -1)))
    assert agree >= 0.5
    assert stats.reduction_vs_raw > 0.8
