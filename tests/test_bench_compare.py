"""Schema'd benchmark records and the trajectory gate
(repro.obs.bench + the benchmarks/compare.py CLI)."""
import json
import os
import subprocess
import sys

import pytest

import repro
from repro.obs import (SCHEMA_VERSION, bench_record, compare, format_report,
                       load_bench, metric, write_bench)
from repro.obs.bench import validate_record


def _rec(name="demo", *, config=None, metrics=None):
    return bench_record(
        name,
        config=config if config is not None else {"smoke": True},
        metrics=metrics if metrics is not None else {
            "lat": metric(1.0, tolerance=0.1),
        })


def test_record_roundtrip(tmp_path):
    rec = _rec(metrics={"lat": metric(1.5, tolerance=0.1),
                        "tp": metric(100, better="higher", tolerance=None)})
    p = tmp_path / "BENCH_demo.json"
    write_bench(p, rec)
    back = load_bench(p)
    assert back == rec
    assert back["schema"] == SCHEMA_VERSION
    assert back["metrics"]["tp"]["tolerance"] is None


def test_schema_rejection(tmp_path):
    for bad in (
        {"schema": "nope/9", "name": "x", "config": {}, "metrics": {}},
        {"schema": SCHEMA_VERSION, "name": "", "config": {}, "metrics": {}},
        {"schema": SCHEMA_VERSION, "name": "x", "metrics": {}},
        {"schema": SCHEMA_VERSION, "name": "x", "config": {},
         "metrics": {"m": {"no_value": 1}}},
        {"schema": SCHEMA_VERSION, "name": "x", "config": {},
         "metrics": {"m": {"value": 1, "better": "sideways"}}},
        {"schema": SCHEMA_VERSION, "name": "x", "config": {},
         "metrics": {"m": {"value": 1, "tolerance": -0.5}}},
        [1, 2, 3],
    ):
        with pytest.raises(ValueError):
            validate_record(bad)
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"schema": "nope"}))
    with pytest.raises(ValueError):
        load_bench(p)


def test_metric_constructor_validation():
    with pytest.raises(ValueError, match="better"):
        metric(1.0, better="sideways")
    with pytest.raises(ValueError, match="tolerance"):
        metric(1.0, tolerance=-1)


def test_regression_directions():
    base = _rec(metrics={"lat": metric(1.0, tolerance=0.1),
                         "tp": metric(100.0, better="higher",
                                      tolerance=0.1)})
    # lower-is-better metric grows past tolerance -> regressed
    cur = _rec(metrics={"lat": metric(1.2, tolerance=0.1),
                        "tp": metric(100.0, better="higher", tolerance=0.1)})
    ok, deltas = compare(cur, base)
    assert not ok
    assert {d.key: d.status for d in deltas}["lat"] == "regressed"
    # higher-is-better metric shrinking past tolerance -> regressed
    cur = _rec(metrics={"lat": metric(1.0, tolerance=0.1),
                        "tp": metric(80.0, better="higher", tolerance=0.1)})
    ok, deltas = compare(cur, base)
    assert not ok
    assert {d.key: d.status for d in deltas}["tp"] == "regressed"
    # inside tolerance both ways -> ok
    cur = _rec(metrics={"lat": metric(1.05, tolerance=0.1),
                        "tp": metric(95.0, better="higher", tolerance=0.1)})
    ok, deltas = compare(cur, base)
    assert ok
    assert all(d.status == "ok" for d in deltas)


def test_improvement_reported_and_passes():
    base = _rec(metrics={"lat": metric(1.0, tolerance=0.1)})
    cur = _rec(metrics={"lat": metric(0.5, tolerance=0.1)})
    ok, deltas = compare(cur, base)
    assert ok
    assert deltas[0].status == "improved"


def test_informational_never_fails():
    base = _rec(metrics={"wall": metric(1.0, tolerance=None)})
    cur = _rec(metrics={"wall": metric(50.0, tolerance=None)})
    ok, deltas = compare(cur, base)
    assert ok
    assert deltas[0].status == "info"


def test_baseline_tolerance_gates_not_current():
    # the current record claims a loose tolerance; the baseline's tight one
    # must still gate
    base = _rec(metrics={"lat": metric(1.0, tolerance=0.01)})
    cur = _rec(metrics={"lat": metric(1.5, tolerance=9.9)})
    ok, _ = compare(cur, base)
    assert not ok


def test_missing_gated_metric_fails():
    base = _rec(metrics={"lat": metric(1.0, tolerance=0.1),
                         "wall": metric(2.0, tolerance=None)})
    cur = _rec(metrics={})
    ok, deltas = compare(cur, base)
    assert not ok
    st = {d.key: d.status for d in deltas}
    assert st["lat"] == "missing"          # gated: fails
    assert st["wall"] == "info"            # informational: reported only


def test_new_metric_reported_ok():
    base = _rec(metrics={"lat": metric(1.0, tolerance=0.1)})
    cur = _rec(metrics={"lat": metric(1.0, tolerance=0.1),
                        "extra": metric(5.0)})
    ok, deltas = compare(cur, base)
    assert ok
    assert {d.key: d.status for d in deltas}["extra"] == "new"


def test_name_mismatch_fails():
    ok, deltas = compare(_rec("a"), _rec("b"))
    assert not ok and deltas[0].status == "name-mismatch"


def test_config_drift():
    base = _rec(config={"smoke": True, "requests": 32})
    cur = _rec(config={"smoke": False, "requests": 32})
    ok, deltas = compare(cur, base)
    assert not ok
    assert any(d.status == "config-drift" for d in deltas)
    ok, deltas = compare(cur, base, allow_config_drift=True)
    assert ok
    assert any(d.key == "config.smoke" and d.status == "info"
               for d in deltas)


def test_zero_baseline_compares_absolutely():
    base = _rec(metrics={"err": metric(0.0, tolerance=1e-9)})
    ok, _ = compare(_rec(metrics={"err": metric(5e-10, tolerance=1e-9)}),
                    base)
    assert ok
    ok, deltas = compare(_rec(metrics={"err": metric(1e-6, tolerance=1e-9)}),
                         base)
    assert not ok and deltas[0].status == "regressed"


def test_format_report_mentions_failures():
    ok, deltas = compare(
        _rec(metrics={"lat": metric(9.0, tolerance=0.1)}),
        _rec(metrics={"lat": metric(1.0, tolerance=0.1)}))
    text = format_report(deltas)
    assert "REGRESSED" in text and "lat" in text and "summary:" in text


def test_compare_cli_exit_codes(tmp_path):
    # repro is a namespace package (no __init__.py): locate via __path__
    src_dir = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    repo = os.path.dirname(src_dir)
    cli = os.path.join(repo, "benchmarks", "compare.py")
    base_p, good_p, bad_p = (tmp_path / n for n in
                             ("base.json", "good.json", "bad.json"))
    write_bench(base_p, _rec(metrics={"lat": metric(1.0, tolerance=0.1)}))
    write_bench(good_p, _rec(metrics={"lat": metric(1.0, tolerance=0.1)}))
    write_bench(bad_p, _rec(metrics={"lat": metric(9.0, tolerance=0.1)}))
    env = dict(os.environ, PYTHONPATH=src_dir)

    def run(*argv):
        return subprocess.run([sys.executable, cli, *argv],
                              capture_output=True, text=True, env=env)

    r = run("--current", str(good_p), "--baseline", str(base_p))
    assert r.returncode == 0, r.stderr
    assert "PASS" in r.stdout
    r = run("--current", str(bad_p), "--baseline", str(base_p))
    assert r.returncode == 1
    assert "REGRESSED" in r.stdout and "FAIL" in r.stdout
    r = run("--check", str(base_p))
    assert r.returncode == 0 and "valid" in r.stdout
