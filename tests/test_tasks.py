"""Multi-task serving: head registry, per-task distortion, bit allocation,
task negotiation, and the MultiTaskGateway end to end."""
import json
import math

import jax
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.data.synthetic import shapes_batch_iterator
from repro.models.cnn import init_cnn
from repro.pipeline import (Capabilities, NegotiationError, negotiate,
                            negotiate_tasks)
from repro.serve import (LinearCostModel, OperatingPoint, RDPoint,
                         SerialExecutor, TenantRequest, TenantSpec,
                         load_or_build_rd_table, rd_table_from_json,
                         rd_table_to_json)
from repro.tasks import (BitAllocationController, HeadConfig,
                         MultiTaskGateway, MultiTaskResponse,
                         available_heads, build_task_rd_tables,
                         divergence_to_db, get_head, init_head_bank,
                         load_or_build_task_tables, register_head, run_heads,
                         task_divergences, task_set_key)
from repro.tasks.heads import TaskHead

# ---------------------------------------------------------------------------
# Hand-written allocation tables: four ops, shared wire bits, documented
# per-task quality so every policy branch is checkable by eye
# ---------------------------------------------------------------------------

OP_A = OperatingPoint(c=4, bits=2, backend="rans")     # 1000 bits
OP_B = OperatingPoint(c=4, bits=4, backend="rans")     # 2000 bits
OP_C = OperatingPoint(c=8, bits=4, backend="rans")     # 4000 bits
OP_D = OperatingPoint(c=8, bits=8, backend="rans")     # 8000 bits

_QUAL = {  # task -> quality dB at (A, B, C, D)
    "a": (10.0, 20.0, 30.0, 40.0),
    "b": (5.0, 12.0, 25.0, 35.0),
    "c": (2.0, 8.0, 15.0, 30.0),
}
_BITS = {OP_A: 1000.0, OP_B: 2000.0, OP_C: 4000.0, OP_D: 8000.0}


def _tables(tasks=("a", "b", "c")):
    out = {}
    for t in tasks:
        out[t] = [RDPoint(op, bits_per_example=_BITS[op], psnr_db=q)
                  for op, q in zip((OP_A, OP_B, OP_C, OP_D), _QUAL[t])]
    return out


def test_alloc_picks_cheapest_point_meeting_every_floor():
    ctl = BitAllocationController(_tables(), floors={"a": 18.0, "b": 10.0})
    d = ctl.select(("a", "b"))
    assert d.op == OP_B and d.bits_per_example == 2000.0
    assert d.degraded == ()
    assert d.quality_db("a") == 20.0 and d.quality_db("b") == 12.0


def test_alloc_no_floors_means_cheapest_overall():
    ctl = BitAllocationController(_tables())
    assert ctl.select(("a", "b", "c")).op == OP_A


def test_alloc_declared_subset_never_costs_more():
    ctl = BitAllocationController(
        _tables(), floors={"a": 18.0, "b": 24.0, "c": 7.0})
    full = ctl.select(("a", "b", "c"))          # b's floor forces OP_C
    sub = ctl.select(("a",))                    # a alone is happy at OP_B
    assert full.op == OP_C
    assert sub.bits_per_example <= full.bits_per_example
    assert sub.op == OP_B


def test_alloc_degrades_lowest_weight_first_under_budget_pressure():
    ctl = BitAllocationController(
        _tables(), weights={"a": 3.0, "b": 1.0, "c": 0.5},
        floors={"a": 18.0, "b": 10.0, "c": 28.0})
    # c's floor needs OP_D (8000 bits); budget only admits A/B/C -> c is
    # the lightest task, so it alone is degraded and OP_B still serves a+b
    d = ctl.select(("a", "b", "c"), bit_budget=4000.0)
    assert d.degraded == ("c",)
    assert d.op == OP_B


def test_alloc_best_effort_when_every_floor_relaxed():
    ctl = BitAllocationController(
        _tables(), weights={"a": 3.0, "b": 1.0, "c": 0.5},
        floors={"a": 50.0, "b": 50.0, "c": 50.0})
    d = ctl.select(("a", "b", "c"), bit_budget=4000.0)
    # relaxation order is ascending weight; best-effort picks the fitting
    # point with the highest weighted quality (OP_C here)
    assert d.degraded == ("c", "b", "a")
    assert d.op == OP_C


def test_alloc_nothing_fits_serves_cheapest_never_drops():
    ctl = BitAllocationController(_tables(), floors={"a": 18.0})
    d = ctl.select(("a", "b"), bit_budget=500.0)
    assert d.op == OP_A                        # cheapest overall
    assert "a" in d.degraded                   # floor unmet, recorded


def test_alloc_is_declaration_order_independent():
    ctl = BitAllocationController(_tables(), floors={"a": 18.0, "b": 10.0})
    assert ctl.select(("b", "a")) == ctl.select(("a", "b"))
    assert ctl.select(("a", "a", "b")) == ctl.select(("a", "b"))


def test_alloc_per_task_bits_are_weight_proportional_and_sum():
    ctl = BitAllocationController(_tables(), weights={"a": 3.0, "b": 1.0})
    d = ctl.select(("a", "b"))
    bits = dict(d.per_task_bits)
    assert bits["a"] == pytest.approx(3 * bits["b"])
    assert sum(bits.values()) == pytest.approx(d.bits_per_example)


def test_alloc_independent_streams_cost_at_least_the_shared_stream():
    ctl = BitAllocationController(
        _tables(), floors={"a": 18.0, "b": 10.0, "c": 7.0})
    shared = ctl.select(("a", "b", "c")).bits_per_example
    independent = ctl.independent_bits(("a", "b", "c"))
    assert independent >= shared
    # and here strictly: three floors each need >= OP_B independently
    assert independent > shared


def test_alloc_validation_errors():
    with pytest.raises(ValueError, match="empty task table"):
        BitAllocationController({})
    with pytest.raises(ValueError, match="empty RD table"):
        BitAllocationController({"a": []})
    with pytest.raises(ValueError, match="weight"):
        BitAllocationController(_tables(), weights={"a": 0.0})
    ctl = BitAllocationController(_tables(("a", "b")))
    with pytest.raises(KeyError, match="no RD table"):
        ctl.select(("a", "zz"))
    with pytest.raises(ValueError, match="empty declared"):
        ctl.select(())


@given(data=st.data() if HAVE_HYPOTHESIS else None)
@settings(max_examples=40, deadline=None)
def test_alloc_monotone_in_declared_set_when_no_degradation(data):
    """Fewer declared tasks never cost more bits — the billing property —
    whenever every floor is servable within budget (floors anchored at a
    common op guarantee the non-degraded regime)."""
    names = ("a", "b", "c", "d")
    n_ops = data.draw(st.integers(2, 5), label="n_ops")
    ops = [OperatingPoint(c=8, bits=i + 1, backend="rans")
           for i in range(n_ops)]
    wire = data.draw(st.lists(st.integers(100, 10_000), min_size=n_ops,
                              max_size=n_ops, unique=True), label="wire")
    qual = {t: data.draw(st.lists(st.integers(0, 400), min_size=n_ops,
                                  max_size=n_ops), label=f"q_{t}")
            for t in names}
    tables = {t: [RDPoint(op, bits_per_example=float(w), psnr_db=q / 10.0)
                  for op, w, q in zip(ops, wire, qual[t])]
              for t in names}
    anchor = data.draw(st.integers(0, n_ops - 1), label="anchor")
    floors = {t: qual[t][anchor] / 10.0 - 0.05 for t in names}
    weights = {t: data.draw(st.floats(0.1, 10.0, allow_nan=False),
                            label=f"w_{t}") for t in names}
    ctl = BitAllocationController(tables, weights=weights, floors=floors)
    declared = tuple(data.draw(
        st.lists(st.sampled_from(names), min_size=2, max_size=4,
                 unique=True), label="declared"))
    subset = tuple(data.draw(
        st.lists(st.sampled_from(declared), min_size=1,
                 max_size=len(declared), unique=True), label="subset"))
    full = ctl.select(declared)
    sub = ctl.select(subset)
    assert full.degraded == () and sub.degraded == ()
    assert sub.bits_per_example <= full.bits_per_example


# ---------------------------------------------------------------------------
# Task negotiation (pipeline.negotiate_tasks)
# ---------------------------------------------------------------------------

def test_negotiate_tasks_passthrough_and_dedupe():
    assert negotiate_tasks(("b", "a", "b"), None) == ("b", "a")
    caps = Capabilities()                      # task_heads None = serves all
    assert negotiate_tasks(("x", "y"), caps) == ("x", "y")


def test_negotiate_tasks_drops_unsupported_when_downgrade_allowed():
    caps = Capabilities(task_heads=("classify", "embed"), downgrade=True)
    assert negotiate_tasks(("classify", "detect", "embed"), caps) == \
        ("classify", "embed")


def test_negotiate_tasks_refuses_without_downgrade():
    caps = Capabilities(task_heads=("classify",), downgrade=False)
    with pytest.raises(NegotiationError, match="downgrade is disabled"):
        negotiate_tasks(("classify", "detect"), caps)


def test_negotiate_tasks_refuses_when_nothing_survives():
    caps = Capabilities(task_heads=("classify",), downgrade=True)
    with pytest.raises(NegotiationError, match="none of the declared"):
        negotiate_tasks(("detect", "embed"), caps)


def test_negotiate_tasks_empty_declaration_is_an_error():
    with pytest.raises(ValueError, match="empty task declaration"):
        negotiate_tasks((), None)


def test_foreign_wire_profile_refused_regardless_of_task_subset():
    """Task negotiation never bypasses wire-profile refusal: however few
    heads a tenant declares, a foreign container profile still refuses."""
    caps = Capabilities(profiles=(99,), task_heads=("classify",),
                        downgrade=True)
    assert negotiate_tasks(("classify",), caps) == ("classify",)
    with pytest.raises(NegotiationError, match="wire profile"):
        negotiate(OperatingPoint(c=8, bits=4, backend="rans"), caps)


@given(declared=(st.lists(st.sampled_from(("w", "x", "y", "z")), min_size=1,
                          max_size=4, unique=True)
                 if HAVE_HYPOTHESIS else None),
       served=(st.lists(st.sampled_from(("w", "x", "y", "z")), min_size=0,
                        max_size=4, unique=True)
               if HAVE_HYPOTHESIS else None))
@settings(max_examples=60, deadline=None)
def test_negotiate_tasks_result_is_served_subsequence_or_refusal(declared,
                                                                 served):
    caps = Capabilities(task_heads=tuple(served), downgrade=True)
    try:
        out = negotiate_tasks(tuple(declared), caps)
    except NegotiationError:
        assert not (set(declared) & set(served))
        return
    assert out == tuple(t for t in declared if t in served)
    assert set(out) <= set(served)


def test_negotiate_downgrade_rebases_context():
    """Downgrading an adaptive-context rans point onto a plain-rans decoder
    must drop the context upgrade too (the wire backend it implied)."""
    caps = Capabilities(backends=("rans",), downgrade=True)
    op = OperatingPoint(c=8, bits=4, backend="rans", context="adaptive")
    out = negotiate(op, caps)
    assert out.wire_backend == "rans"
    assert out.resolve().context == "static"


# ---------------------------------------------------------------------------
# Head registry + forwards (tiny real system)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_task_system():
    cnn_cfg = smoke_config()._replace(input_size=32)
    data_cfg = smoke_data_config()._replace(image_size=32, batch_size=8)
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    bank = {c: (init_baf_conv(jax.random.PRNGKey(c),
                              BaFConvConfig(c=c, q=cnn_cfg.split_q,
                                            hidden=8)),
                np.arange(c)) for c in (4, 8)}
    imgs, _ = next(shapes_batch_iterator(data_cfg, seed=5))
    head_cfg = HeadConfig(split_p=cnn_cfg.split_p,
                          num_classes=cnn_cfg.num_classes)
    head_bank = init_head_bank(jax.random.PRNGKey(99), head_cfg)
    from repro.models.cnn import cnn_edge
    z = jax.jit(lambda p, i: cnn_edge(p, i)[1])(params, np.asarray(imgs))
    return params, bank, np.asarray(imgs), head_cfg, head_bank, np.asarray(z)


def test_registry_serves_the_three_builtin_heads():
    assert set(available_heads()) >= {"classify", "detect", "embed"}
    with pytest.raises(KeyError, match="registered"):
        get_head("nope")
    with pytest.raises(ValueError, match="already registered"):
        register_head(TaskHead(name="classify", init=None, forward=None,
                               divergence=None))


def test_head_config_validates_head_dim():
    with pytest.raises(ValueError, match="not divisible"):
        _ = HeadConfig(split_p=64, d_model=30, n_heads=4).head_dim


def test_head_output_shapes_and_determinism(tiny_task_system):
    params, _, _, head_cfg, head_bank, z = tiny_task_system
    out = run_heads(params, head_bank, z, available_heads(), head_cfg)
    b, h, w, _ = z.shape
    assert out["classify"].shape == (b, head_cfg.num_classes)
    assert out["detect"].shape == (b, h, w,
                                   head_cfg.box_fields + head_cfg.num_classes)
    assert out["embed"].shape == (b, head_cfg.embed_dim)
    # embeddings are L2-normalized rows
    assert np.allclose(np.linalg.norm(out["embed"], axis=-1), 1.0, atol=1e-4)
    again = run_heads(params, head_bank, z, available_heads(), head_cfg)
    for t in out:
        assert np.array_equal(out[t], again[t])


def test_head_divergence_zero_on_identical_outputs(tiny_task_system):
    params, _, _, head_cfg, head_bank, z = tiny_task_system
    out = run_heads(params, head_bank, z, available_heads(), head_cfg)
    for t, y in out.items():
        assert get_head(t).divergence(y, y) == pytest.approx(0.0, abs=1e-9)


# ---------------------------------------------------------------------------
# Per-task distortion tables
# ---------------------------------------------------------------------------

def test_divergence_to_db_monotone_and_capped():
    assert divergence_to_db(0.1) < divergence_to_db(0.01)
    assert divergence_to_db(0.0) == divergence_to_db(1e-30) == 120.0


def test_task_divergences_intersects_task_sets(tiny_task_system):
    params, _, _, head_cfg, head_bank, z = tiny_task_system
    ref = run_heads(params, head_bank, z, ("classify", "embed"), head_cfg)
    out = run_heads(params, head_bank, z, ("classify",), head_cfg)
    d = task_divergences(ref, out)
    assert set(d) == {"classify"}
    assert d["classify"] == pytest.approx(0.0, abs=1e-9)


def test_build_task_rd_tables_shares_bits_across_tasks(tiny_task_system):
    params, bank, imgs, head_cfg, head_bank, _ = tiny_task_system
    ops = [OperatingPoint(c=4, bits=4, backend="rans"),
           OperatingPoint(c=8, bits=8, backend="rans")]
    tables = build_task_rd_tables(params, bank, imgs[:4],
                                  head_bank=head_bank, head_cfg=head_cfg,
                                  ops=ops)
    assert set(tables) == set(head_bank)
    for t, pts in tables.items():
        assert [p.op for p in pts] == ops
        assert all(math.isfinite(p.psnr_db) for p in pts)
        assert all(p.kl >= 0.0 for p in pts)
    # one shared stream: wire bits identical across tasks at each op
    for i in range(len(ops)):
        bits = {t: tables[t][i].bits_per_example for t in tables}
        assert len(set(bits.values())) == 1
        assert min(bits.values()) > 0


def test_build_task_rd_tables_rejects_op_outside_bank(tiny_task_system):
    params, bank, imgs, head_cfg, head_bank, _ = tiny_task_system
    with pytest.raises(ValueError, match="bank"):
        build_task_rd_tables(params, bank, imgs[:2], head_bank=head_bank,
                             head_cfg=head_cfg,
                             ops=[OperatingPoint(c=16, bits=4,
                                                 backend="rans")])


# ---------------------------------------------------------------------------
# Disk caches: task identity must be part of the key (the staleness fix)
# ---------------------------------------------------------------------------

def _counting_build(table):
    calls = {"n": 0}

    def build():
        calls["n"] += 1
        return table
    return calls, build


def test_task_table_cache_hits_only_on_identical_task_identity(tmp_path):
    path = tmp_path / "cache.json"
    ops = [OP_A, OP_B]
    tables = {t: pts[:2] for t, pts in _tables(("a", "b")).items()}
    key = task_set_key(("a", "b"), {"a": 2.0})
    calls, build = _counting_build(tables)

    first = load_or_build_task_tables(path, {"seed": 1}, build,
                                      ops=ops, tasks=key)
    assert calls["n"] == 1
    again = load_or_build_task_tables(path, {"seed": 1}, build,
                                      ops=ops, tasks=key)
    assert calls["n"] == 1                      # cache hit
    for t in tables:                            # NaN fields defeat ==
        for x, y, z in zip(again[t], first[t], tables[t]):
            assert (x.op, x.bits_per_example, x.psnr_db) == \
                (y.op, y.bits_per_example, y.psnr_db) == \
                (z.op, z.bits_per_example, z.psnr_db)

    # different weight vector -> stale -> rebuild
    load_or_build_task_tables(path, {"seed": 1}, build, ops=ops,
                              tasks=task_set_key(("a", "b"), {"a": 3.0}))
    assert calls["n"] == 2
    # different head set -> stale -> rebuild
    load_or_build_task_tables(path, {"seed": 1}, build, ops=ops,
                              tasks=task_set_key(("a",)))
    assert calls["n"] == 3
    # corrupt file -> rebuild, never crash
    path.write_text("{not json")
    load_or_build_task_tables(path, {"seed": 1}, build, ops=ops, tasks=key)
    assert calls["n"] == 4


def test_rd_table_cache_distinguishes_task_aware_sweeps(tmp_path):
    """The staleness fix on the *existing* single-table cache: a cache
    written without task identity must rebuild for a task-aware caller,
    and vice versa."""
    path = tmp_path / "rd.json"
    table = _tables(("a",))["a"]
    ops = [OP_A, OP_B, OP_C, OP_D]
    calls, build = _counting_build(table)

    load_or_build_rd_table(path, {"seed": 1}, build, ops=ops)
    assert calls["n"] == 1
    load_or_build_rd_table(path, {"seed": 1}, build, ops=ops)
    assert calls["n"] == 1                      # plain caller hits
    tkey = task_set_key(("classify", "detect"), {"detect": 3.0})
    load_or_build_rd_table(path, {"seed": 1}, build, ops=ops, tasks=tkey)
    assert calls["n"] == 2                      # task-aware caller rebuilds
    load_or_build_rd_table(path, {"seed": 1}, build, ops=ops, tasks=tkey)
    assert calls["n"] == 2                      # then hits
    load_or_build_rd_table(path, {"seed": 1}, build, ops=ops,
                           tasks=task_set_key(("classify",)))
    assert calls["n"] == 3                      # different head set rebuilds
    load_or_build_rd_table(path, {"seed": 1}, build, ops=ops)
    assert calls["n"] == 4                      # plain caller is stale again


def test_rd_point_p_over_i_round_trips_and_legacy_rows_parse():
    table = [RDPoint(OP_A, bits_per_example=1000.0, psnr_db=20.0,
                     p_over_i=0.25),
             RDPoint(OP_B, bits_per_example=2000.0, psnr_db=25.0)]
    back = rd_table_from_json(rd_table_to_json(table))
    assert back[0].p_over_i == 0.25
    assert math.isnan(back[1].p_over_i)
    legacy = rd_table_to_json(table)
    for row in legacy:
        row.pop("p_over_i", None)               # pre-p_over_i cache rows
    old = rd_table_from_json(legacy)
    assert all(math.isnan(p.p_over_i) for p in old)


# ---------------------------------------------------------------------------
# MultiTaskGateway end to end
# ---------------------------------------------------------------------------

OP_LO = OperatingPoint(c=4, bits=2, backend="rans")
OP_HI = OperatingPoint(c=8, bits=6, backend="rans")

# hand-written allocation tables over REAL ops: classify alone is happy at
# the cheap point, detect's floor forces the expensive one
GW_TABLES = {
    "classify": [RDPoint(OP_LO, 1000.0, 20.0), RDPoint(OP_HI, 4000.0, 30.0)],
    "detect":   [RDPoint(OP_LO, 1000.0, 8.0),  RDPoint(OP_HI, 4000.0, 25.0)],
    "embed":    [RDPoint(OP_LO, 1000.0, 15.0), RDPoint(OP_HI, 4000.0, 28.0)],
}
GW_FLOORS = {"classify": 15.0, "detect": 20.0, "embed": 10.0}


def _task_gateway(parts, *, tenants, allocator="default", **kw):
    params, bank, _, head_cfg, head_bank, _ = parts
    if allocator == "default":
        allocator = BitAllocationController(GW_TABLES, floors=GW_FLOORS)
    kw.setdefault("executor",
                  SerialExecutor(cost=LinearCostModel(0.004, 0.001)))
    kw.setdefault("max_batch", 4)
    kw.setdefault("batch_window_s", 0.01)
    return MultiTaskGateway(params, bank, tenants=tenants,
                            head_bank=head_bank, head_cfg=head_cfg,
                            allocator=allocator, **kw)


def _mixed_workload(imgs, n=8):
    return [TenantRequest(("full", "lite")[i % 2], imgs[i % len(imgs)],
                          t_submit=0.001 * i) for i in range(n)]


@pytest.fixture(scope="module")
def task_gateway_run(tiny_task_system):
    gw = _task_gateway(tiny_task_system, tenants=[
        TenantSpec("full"),                     # undeclared -> all heads
        TenantSpec("lite", tasks=("classify",))])
    work = _mixed_workload(tiny_task_system[2])
    responses, tel = gw.serve_tenants(work)
    return gw, work, responses, tel


def test_gateway_fans_out_declared_task_sets(task_gateway_run):
    gw, _, responses, _ = task_gateway_run
    assert gw.task_sets["full"] == ("classify", "detect", "embed")
    assert gw.task_sets["lite"] == ("classify",)
    for r in responses["full"]:
        assert isinstance(r, MultiTaskResponse)
        assert set(r.outputs) == {"classify", "detect", "embed"}
        assert all(np.isfinite(v).all() for v in r.outputs.values())
        assert np.array_equal(r.logits, r.outputs["classify"])
    for r in responses["lite"]:
        assert set(r.outputs) == {"classify"}
        assert r.op.resolve() == OP_LO.resolve()
    for r in responses["full"]:
        assert r.op.resolve() == OP_HI.resolve()


def test_gateway_runs_each_head_once_per_decoded_batch(task_gateway_run):
    gw, work, _, _ = task_gateway_run
    assert gw.decode_calls >= 1
    assert set(gw.head_calls) == {"classify", "detect", "embed"}
    # one decode + one restore per batch serves every subscribed head: no
    # head ever runs more often than the batches themselves
    for t, n in gw.head_calls.items():
        assert 1 <= n <= gw.decode_calls
    assert gw.head_calls["classify"] == gw.decode_calls


def test_gateway_declared_subset_tenant_pays_fewer_bits(task_gateway_run):
    _, _, _, tel = task_gateway_run
    per = tel.per_tenant()
    assert per["full"]["count"] == per["lite"]["count"] == 4
    assert per["lite"]["bits_on_wire"] < per["full"]["bits_on_wire"]


def test_gateway_mixed_population_replays_bit_identically(tiny_task_system):
    outs = []
    for _ in range(2):
        gw = _task_gateway(tiny_task_system, tenants=[
            TenantSpec("full"),
            TenantSpec("lite", tasks=("classify",))])
        responses, tel = gw.serve_tenants(
            _mixed_workload(tiny_task_system[2]))
        outs.append((responses, tel.per_tenant()))
    (r1, t1), (r2, t2) = outs
    assert t1 == t2
    for tenant in r1:
        for a, b in zip(r1[tenant], r2[tenant]):
            assert a.tasks == b.tasks and set(a.outputs) == set(b.outputs)
            for task in a.outputs:
                assert np.array_equal(a.outputs[task], b.outputs[task])


def test_gateway_negotiates_task_sets_at_construction(tiny_task_system):
    caps = Capabilities(task_heads=("classify", "embed"), downgrade=True)
    gw = _task_gateway(
        tiny_task_system, capabilities=caps,
        tenants=[TenantSpec("t", tasks=("classify", "detect"))])
    assert gw.task_sets["t"] == ("classify",)   # detect dropped up front
    with pytest.raises(NegotiationError):
        _task_gateway(
            tiny_task_system,
            capabilities=Capabilities(task_heads=("classify",),
                                      downgrade=False),
            tenants=[TenantSpec("t", tasks=("classify", "detect"))])
    with pytest.raises(ValueError, match="no head in the bank"):
        _task_gateway(tiny_task_system,
                      tenants=[TenantSpec("t", tasks=("nope",))])


def test_gateway_requires_allocator_tables_for_every_head(tiny_task_system):
    partial = {t: pts for t, pts in GW_TABLES.items() if t != "embed"}
    with pytest.raises(ValueError, match="no RD table"):
        _task_gateway(
            tiny_task_system, tenants=[TenantSpec("t")],
            allocator=BitAllocationController(partial))


def test_gateway_without_allocator_still_bounds_outputs(tiny_task_system):
    gw = _task_gateway(tiny_task_system, allocator=None,
                       default_op=OP_LO,
                       tenants=[TenantSpec("lite", tasks=("classify",))])
    responses, _ = gw.serve_tenants(
        [TenantRequest("lite", tiny_task_system[2][0])])
    assert set(responses["lite"][0].outputs) == {"classify"}
    assert responses["lite"][0].op.resolve() == OP_LO.resolve()


def test_gateway_single_tenant_serve_returns_full_fanout(tiny_task_system):
    gw = _task_gateway(tiny_task_system, default_op=OP_HI,
                       tenants=[TenantSpec("t")])
    responses, _ = gw.serve(tiny_task_system[2][:4])
    assert [r.req_id for r in responses] == [0, 1, 2, 3]
    for r in responses:
        assert isinstance(r, MultiTaskResponse)
        assert set(r.outputs) == {"classify", "detect", "embed"}


def test_gateway_counts_task_requests_in_metrics(task_gateway_run):
    _, _, _, tel = task_gateway_run
    counts = {}
    for name, labels, metric in tel.metrics.collect():
        if name == "task_requests_total":
            counts[(labels["tenant"], labels["task"])] = metric.value
    assert counts[("full", "detect")] == 4
    assert counts[("lite", "classify")] == 4
    assert ("lite", "detect") not in counts
