"""Deterministic virtual-clock tracing through the serving gateway
(repro.obs.trace + the tracer/metrics wiring in repro.serve.gateway)."""
import json

import jax
import numpy as np
import pytest

from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.data.synthetic import shapes_batch_iterator
from repro.models.cnn import init_cnn
from repro.obs import (MetricsRegistry, Tracer, hooks, reconcile_trace,
                       validate_chrome_trace)
from repro.pipeline import OperatingPoint
from repro.serve import (ChannelConfig, LinearCostModel, MultiQueueExecutor,
                         MultiTenantGateway, QueueDepthAdmission,
                         ServingGateway, SimulatedChannel, TenantRequest,
                         TenantSpec)


@pytest.fixture(scope="module")
def tiny_system():
    cnn_cfg = smoke_config()._replace(input_size=32)
    data_cfg = smoke_data_config()._replace(image_size=32, batch_size=8)
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    bank = {c: (init_baf_conv(jax.random.PRNGKey(c),
                              BaFConvConfig(c=c, q=cnn_cfg.split_q, hidden=8)),
                np.arange(c)) for c in (4, 8)}
    imgs, _ = next(shapes_batch_iterator(data_cfg, seed=5))
    return params, bank, np.asarray(imgs)


def _make_mt(params, bank, *, tracer=None, metrics=None, n_tenants=4):
    return MultiTenantGateway(
        params, bank, tenants=[TenantSpec(f"t{i}") for i in range(n_tenants)],
        channel_cfg=ChannelConfig(bandwidth_bps=50e6, base_latency_s=0.001),
        default_op=OperatingPoint(c=8, bits=8), max_batch=4,
        batch_window_s=0.002,
        executor=MultiQueueExecutor(2, cost=LinearCostModel(0.004, 0.001)),
        admission=QueueDepthAdmission(max_depth=3),
        tracer=tracer, metrics=metrics)


def _workload(imgs, n=24, n_tenants=4):
    return [TenantRequest(f"t{i % n_tenants}", imgs[i % len(imgs)],
                          t_submit=0.0005 * i) for i in range(n)]


# ---------------------------------------------------------------------------
# Tracer unit behaviour
# ---------------------------------------------------------------------------

def test_tracer_validate_nesting():
    tr = Tracer()
    root = tr.span("request", 0.0, 1.0, track="tenant:a")
    tr.span("child", 0.2, 0.8, track="tenant:a", parent=root)
    tr.validate()
    # a child escaping its parent's interval fails validation
    tr.span("bad", 0.5, 1.5, track="tenant:a", parent=root)
    with pytest.raises(ValueError, match="escapes parent"):
        tr.validate()


def test_tracer_rejects_backwards_span():
    tr = Tracer()
    tr.span("x", 1.0, 0.5, track="t")
    with pytest.raises(ValueError):
        tr.validate()


def test_chrome_export_structure():
    tr = Tracer()
    s = tr.span("request", 0.0, 0.001, track="tenant:a", attrs={"op": "8/8"})
    tr.span("part", 0.0, 0.0005, track="tenant:a", parent=s)
    tr.instant("submit", 0.0, track="tenant:a")
    obj = tr.to_chrome()
    n = validate_chrome_trace(obj)
    assert n == len(obj["traceEvents"])
    kinds = {e["ph"] for e in obj["traceEvents"]}
    assert {"X", "i", "M"} <= kinds
    xs = [e for e in obj["traceEvents"] if e["ph"] == "X"]
    assert all(e["dur"] >= 0 and "args" in e for e in xs)
    # microsecond conversion
    root = next(e for e in xs if e["name"] == "request")
    assert root["dur"] == pytest.approx(1000.0)


def test_validate_chrome_trace_rejects_garbage():
    with pytest.raises(ValueError):
        validate_chrome_trace({"no_events": []})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X"}]})  # missing keys
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": "not a list"})


# ---------------------------------------------------------------------------
# Gateway integration: determinism, reconciliation, invariance
# ---------------------------------------------------------------------------

def test_multi_tenant_trace_deterministic_and_reconciles(tiny_system):
    params, bank, imgs = tiny_system
    work = _workload(imgs)

    def run():
        m = MetricsRegistry()
        gw = _make_mt(params, bank, tracer=Tracer(), metrics=m)
        with hooks.active(m):
            _, tel = gw.serve_tenants(work)
        return gw.tracer, tel, m

    tr1, tel1, m1 = run()
    tr2, tel2, _ = run()
    # byte-identical canonical JSON across two fresh runs
    j1, j2 = tr1.to_json(), tr2.to_json()
    assert j1 == j2
    json.loads(j1)                                     # well-formed
    tr1.validate()
    validate_chrome_trace(tr1.to_chrome())
    # span sums reconcile to telemetry total latency within 1e-9 s
    assert reconcile_trace(tr1, tel1) < 1e-9
    # each request root has exactly the four phase children
    roots = tr1.roots("request")
    assert len(roots) == len(tel1.records)
    for root in roots:
        names = sorted(c.name for c in tr1.children(root.span_id))
        assert names == ["channel.transmit", "cloud.compute", "exec.queue",
                         "sched.wait"]
    # shed requests appear as admission.shed instants, not request spans
    assert tel1.shed
    sheds = [i for i in tr1.instants if i.name == "admission.shed"]
    assert len(sheds) == len(tel1.shed)
    # wall-clock stage timers landed in metrics, never in the trace
    assert m1.get("stage_seconds", stage="pipeline.encode",
                  backend="raw") is not None or any(
        n == "stage_seconds" for n, _, _ in m1.collect())


def test_tracing_does_not_perturb_virtual_clock(tiny_system):
    params, bank, imgs = tiny_system
    work = _workload(imgs)
    _, tel_plain = _make_mt(params, bank).serve_tenants(work)
    m = MetricsRegistry()
    gw = _make_mt(params, bank, tracer=Tracer(), metrics=m)
    with hooks.active(m):
        _, tel_traced = gw.serve_tenants(work)
    assert tel_plain.records == tel_traced.records
    assert tel_plain.shed == tel_traced.shed


def test_single_tenant_serve_traces(tiny_system):
    params, bank, imgs = tiny_system
    tr = Tracer()
    gw = ServingGateway(
        params, bank, default_op=OperatingPoint(c=8, bits=8), max_batch=4,
        channel=SimulatedChannel(ChannelConfig(bandwidth_bps=20e6,
                                               base_latency_s=0.005)),
        tracer=tr, metrics=MetricsRegistry())
    _, tel = gw.serve(imgs[:6])
    tr.validate()
    validate_chrome_trace(tr.to_chrome())
    assert len(tr.roots("request")) == len(tel.records) == 6
    assert reconcile_trace(tr, tel) < 1e-9
    # executor gauges exported at end of serve
    assert gw.metrics.get("executor_utilization") is not None


def test_reconcile_requires_span_per_record(tiny_system):
    params, bank, imgs = tiny_system
    gw = _make_mt(params, bank, tracer=Tracer(), metrics=None)
    _, tel = gw.serve_tenants(_workload(imgs, n=8))
    # a fresh empty tracer cannot reconcile a populated telemetry
    with pytest.raises(ValueError, match="no request span"):
        reconcile_trace(Tracer(), tel)
