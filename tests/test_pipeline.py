"""Pod-boundary activation compression (Tier C) — multi-device subprocess."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from repro.distributed.pipeline import (_dequantize_stream, _quantize_stream,
                                        wire_bytes)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
       "JAX_PLATFORMS": "cpu",
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}


def test_stream_quant_roundtrip(rng):
    x = jnp.asarray(rng.normal(size=(2, 16, 32)).astype(np.float32)) * 3
    codes, mn, mx = _quantize_stream(x, 8)
    y = _dequantize_stream(codes, mn, mx, 8, jnp.float32)
    step = (np.asarray(mx, np.float32) - np.asarray(mn, np.float32)) / 255
    assert (np.abs(np.asarray(y - x)) <= 0.51 * step + 1e-4).all()


def test_wire_bytes_accounting():
    x = jnp.zeros((4, 64, 256))
    comp8, raw = wire_bytes(x, 8)
    comp4, _ = wire_bytes(x, 4)
    assert raw == x.size * 2
    assert comp8 == x.size + 256 * 4      # uint8 codes + fp16 min/max
    assert comp4 == x.size // 2 + 256 * 4


def test_pod_transfer_multidevice():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.baf import BaFStreamConfig, init_baf_stream
from repro.compat import set_mesh
from repro.distributed.pipeline import compressed_pod_transfer, subset_pod_transfer
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 32), jnp.float32)
with set_mesh(mesh):
    xs = jax.device_put(x, NamedSharding(mesh, P()))
    y = jax.jit(lambda t: compressed_pod_transfer(t, mesh, bits=8,
                                                  dtype=jnp.float32))(xs)
    # both pods hold identical x, so the received tensor ~= x
    err = float(jnp.max(jnp.abs(y - x)))
    assert err < 0.05, err
    baf = init_baf_stream(jax.random.PRNGKey(1),
                          BaFStreamConfig(c=8, d_in=32, hidden=16))
    w = jax.random.normal(jax.random.PRNGKey(2), (32, 32)) * 0.05
    z = jax.jit(lambda t: subset_pod_transfer(
        t, mesh, sel_idx=jnp.arange(8), baf_params=baf,
        forward_fn=lambda h: h @ w, bits=8, dtype=jnp.float32))(xs)
    assert z.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(z)))
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
