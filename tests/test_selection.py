"""Paper §3.1 eqs. (2)-(3): correlation-based channel selection."""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.selection import (accumulate_correlation, correlation_matrix_conv,
                                  correlation_matrix_stream, select_channels,
                                  select_channels_greedy, stride2_offsets)


def test_stride2_offsets_cover_everything(rng):
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 3)).astype(np.float32))
    offs = stride2_offsets(x)
    assert len(offs) == 4 and all(o.shape == (2, 4, 4, 3) for o in offs)
    total = sum(float(jnp.sum(o)) for o in offs)
    assert np.isclose(total, float(jnp.sum(x)), rtol=1e-5)


def test_correlation_matches_numpy(rng):
    z = rng.normal(size=(4, 6, 6, 5)).astype(np.float32)
    x = rng.normal(size=(4, 6, 6, 3)).astype(np.float32)
    rho = np.asarray(correlation_matrix_stream(jnp.asarray(z), jnp.asarray(x)))
    zf = z.reshape(-1, 5)
    xf = x.reshape(-1, 3)
    for p in range(5):
        for q in range(3):
            expect = abs(np.corrcoef(zf[:, p], xf[:, q])[0, 1])
            assert np.isclose(rho[p, q], expect, atol=1e-5)


def test_conv_correlation_shape_and_range(rng):
    z = jnp.asarray(rng.normal(size=(2, 4, 4, 6)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 3)).astype(np.float32))
    rho = np.asarray(correlation_matrix_conv(z, x))
    assert rho.shape == (6, 3)
    assert (rho >= -1e-6).all() and (rho <= 1 + 1e-6).all()


def test_perfectly_correlated_channel_selected_first(rng):
    x = rng.normal(size=(2, 8, 8, 3)).astype(np.float32)
    z = rng.normal(size=(2, 4, 4, 4)).astype(np.float32)
    z[..., 2] = x[:, ::2, ::2, 0] + x[:, ::2, ::2, 1]  # built from X -> max rho
    rho = correlation_matrix_conv(jnp.asarray(z), jnp.asarray(x))
    res = select_channels(rho)
    assert res.order[0] == 2


@given(p=st.integers(2, 12), q=st.integers(1, 6), seed=st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_property_greedy_equals_sort(p, q, seed):
    """The paper's iterative re-selection == one descending sort (eq. 3 scores
    don't change as channels are removed) — the equivalence select_channels
    relies on."""
    r = np.random.default_rng(seed)
    rho = r.uniform(0, 1, size=(p, q))
    c = max(1, p // 2)
    greedy = select_channels_greedy(rho, c)
    sorted_ = select_channels(rho).order[:c]
    # ties broken identically (stable sort vs (-total, -p) max key)
    assert np.array_equal(greedy, sorted_)


def test_accumulate_correlation_streaming(rng):
    batches = [
        (jnp.asarray(rng.normal(size=(2, 4, 4, 4)).astype(np.float32)),
         jnp.asarray(rng.normal(size=(2, 8, 8, 2)).astype(np.float32)))
        for _ in range(3)
    ]
    res = accumulate_correlation(batches, conv=True)
    assert res.order.shape == (4,)
    assert (np.diff(res.scores) <= 1e-6).all()  # best-first ordering
