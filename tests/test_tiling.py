"""Paper §3.2: channel tiling into one rectangular image."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.tiling import (tile_batch, tile_channels, tile_grid,
                               untile_batch, untile_channels)


@pytest.mark.parametrize("c,rows,cols", [
    (1, 1, 1), (2, 1, 2), (4, 2, 2), (8, 2, 4), (16, 4, 4),
    (32, 4, 8), (64, 8, 8), (128, 8, 16), (256, 16, 16),
])
def test_grid_matches_paper_formula(c, rows, cols):
    assert tile_grid(c) == (rows, cols)


def test_non_power_of_two_rejected():
    with pytest.raises(ValueError):
        tile_grid(12)


@given(lgc=st.integers(0, 7), h=st.integers(1, 6), w=st.integers(1, 6),
       seed=st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_property_tile_untile_roundtrip(lgc, h, w, seed):
    c = 1 << lgc
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.integers(0, 255, size=(h, w, c)).astype(np.uint8))
    img = tile_channels(x)
    rows, cols = tile_grid(c)
    assert img.shape == (rows * h, cols * w)   # rectangular, no empty area
    back = untile_channels(img, c)
    assert bool(jnp.all(back == x))


def test_batch_roundtrip(rng):
    x = jnp.asarray(rng.integers(0, 255, size=(3, 4, 4, 16)).astype(np.uint8))
    assert bool(jnp.all(untile_batch(tile_batch(x), 16) == x))


def test_channel_placement_row_major(rng):
    # channel k lands at tile (k // cols, k % cols)
    h = w = 2
    c = 8
    x = jnp.stack([jnp.full((h, w), k, jnp.uint8) for k in range(c)], axis=-1)
    img = np.asarray(tile_channels(x))
    rows, cols = tile_grid(c)
    for k in range(c):
        ti, tj = k // cols, k % cols
        assert (img[ti * h:(ti + 1) * h, tj * w:(tj + 1) * w] == k).all()
