"""Entropy-codec benchmark: bits/element + throughput on BaF residuals.

    PYTHONPATH=src python benchmarks/codec_bench.py [--smoke]

Sweeps the wire-codec backends (raw / zlib / rans / rans-ctx) over a
(C, bits) grid of synthetic BaF residual tiles and reports, per point:

  * bits per element of the entropy-coded payload (the quantity RD tables
    and channel budgets are computed from),
  * the order-0 empirical-entropy floor (``core/codec.py``) as the target —
    a context coder may go *below* it by exploiting spatial correlation,
  * encode / decode throughput in MB/s of raw code bytes.

The residual generator mirrors what BaF prediction leaves behind: a small,
spatially smooth error field plus sparse heavy-tailed spikes whose per-
channel amplitude sets the quantizer range (exactly why near-lossless
residual coding pays off — the bulk of the mass lands in a few codes).
Tiles are encoded at deployment granularity (one example per container,
matching the gateway's one-request-per-transmission accounting).

``--smoke`` (CI) shrinks the sweep to < 60 s and **gates** on the paper-
motivated acceptance: rANS payload <= 0.95x zlib payload on 8-bit
residuals, exiting nonzero on failure.

Prints ``name,us_per_call,derived`` CSV rows like benchmarks/run.py and
writes benchmarks/BENCH_codec.json — a schema'd ``repro-bench/1`` record
(repro.obs.bench) that ``benchmarks/compare.py`` gates against the committed
baseline: payload bits/element are deterministic (tight tolerances), MB/s
throughputs are informational (shared CI runners).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax.numpy as jnp

from repro.core import codec as wire
from repro.obs.bench import bench_record, metric, write_bench
from repro.core.quant import compute_quant_params, quantize
from repro.core.tiling import tile_batch

_ROWS: list[str] = []


def _row(name: str, us: float, derived: str):
    line = f"{name},{us:.1f},{derived}"
    _ROWS.append(line)
    print(line, flush=True)


def synthetic_baf_residuals(rng: np.random.Generator, b: int, h: int, w: int,
                            c: int, *, outlier_p: float = 0.003,
                            outlier_scale=(8.0, 40.0)) -> np.ndarray:
    """BaF-like residual field: smooth low-amplitude error + sparse spikes."""
    r = rng.normal(size=(b, h, w, c))
    for _ in range(2):                       # cheap separable smoothing
        r = (r + np.roll(r, 1, axis=1) + np.roll(r, 1, axis=2)) / 3.0
    r /= r.std(axis=(0, 1, 2), keepdims=True)
    amp = rng.uniform(*outlier_scale, size=(1, 1, 1, c))
    spikes = ((rng.random((b, h, w, c)) < outlier_p)
              * rng.normal(size=(b, h, w, c)) * amp)
    return (r + spikes).astype(np.float32)


def quantize_tile(z: np.ndarray, bits: int) -> np.ndarray:
    qp = compute_quant_params(jnp.asarray(z), bits, per_example=True)
    return np.asarray(quantize(jnp.asarray(z), qp)), qp


def bench_point(rng, *, h: int, w: int, c: int, bits: int,
                backends: tuple[str, ...], repeats: int = 1) -> dict:
    z = synthetic_baf_residuals(rng, 1, h, w, c)
    codes, qp = quantize_tile(z, bits)
    tiled = np.asarray(tile_batch(jnp.asarray(codes)))
    stream = tiled.reshape(-1, tiled.shape[-1])
    n = codes.size
    floor_bits = wire.empirical_entropy_bits(codes, bits)
    out = {"h": h, "w": w, "c": c, "bits": bits, "elements": n,
           "entropy_floor_bpe": floor_bits / n}
    for backend in backends:
        data = codes if not wire.backend_wants_tiling(backend) else stream
        t0 = time.perf_counter()
        for _ in range(repeats):
            enc = wire.encode(data, qp, backend=backend)
        enc_s = (time.perf_counter() - t0) / repeats
        blob = enc.to_bytes()
        t0 = time.perf_counter()
        for _ in range(repeats):
            dec, _ = wire.decode(wire.EncodedTensor.from_bytes(blob))
        dec_s = (time.perf_counter() - t0) / repeats
        assert np.array_equal(np.asarray(dec).ravel(), data.ravel()), \
            f"{backend} round-trip mismatch at C={c} bits={bits}"
        mb = n / 1e6                          # one code byte per element
        out[backend] = {
            "payload_bpe": 8 * len(enc.payload) / n,
            "wire_bpe": enc.wire_bits() / n,
            "encode_mb_s": mb / max(enc_s, 1e-9),
            "decode_mb_s": mb / max(dec_s, 1e-9),
        }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI gate, < 60 s")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()
    rng = np.random.default_rng(args.seed)
    backends = ("raw", "zlib", "rans", "rans-ctx")

    if args.smoke:
        grid = [(32, 32, 8, 2), (32, 32, 8, 4), (32, 32, 8, 8),
                (64, 64, 8, 8), (32, 32, 16, 8)]
    else:
        grid = [(32, 32, c, bits) for c in (4, 8, 16)
                for bits in (2, 4, 6, 8)]
        grid += [(64, 64, c, 8) for c in (4, 8, 16)]

    results = {"seed": args.seed, "points": []}
    for h, w, c, bits in grid:
        r = bench_point(rng, h=h, w=w, c=c, bits=bits, backends=backends)
        results["points"].append(r)
        _row(f"codec_{h}x{w}x{c}_{bits}b", 0.0,
             f"floor={r['entropy_floor_bpe']:.2f}bpe "
             + " ".join(f"{b}={r[b]['payload_bpe']:.2f}" for b in backends)
             + f" rans_enc={r['rans']['encode_mb_s']:.2f}MB/s"
               f" rans_dec={r['rans']['decode_mb_s']:.2f}MB/s")

    # -- acceptance gate: rANS must beat zlib by >= 5% on 8-bit residuals --
    pts8 = [p for p in results["points"] if p["bits"] == 8]
    rans8 = sum(p["rans"]["payload_bpe"] * p["elements"] for p in pts8)
    zlib8 = sum(p["zlib"]["payload_bpe"] * p["elements"] for p in pts8)
    ratio = rans8 / zlib8
    results["rans_vs_zlib_8bit"] = ratio
    ok = ratio <= 0.95
    results["acceptance_rans_payload"] = ok
    _row("codec_gate", 0.0,
         f"rans/zlib payload @8bit = {ratio:.3f} "
         f"({'OK' if ok else 'FAIL'} <= 0.95)")

    # context coder vs the order-0 floor on the biggest 8-bit tiles
    big = [p for p in results["points"] if p["bits"] == 8
           and p["h"] * p["w"] * p["c"] >= 16384]
    if big:
        ctx = sum(p["rans-ctx"]["payload_bpe"] * p["elements"] for p in big)
        floor = sum(p["entropy_floor_bpe"] * p["elements"] for p in big)
        results["ctx_vs_floor_8bit"] = ctx / floor
        _row("codec_ctx_floor", 0.0,
             f"rans-ctx/entropy-floor @8bit = {ctx / floor:.3f}")

    # -- schema'd trajectory record (compare.py gates on the baseline's
    # tolerances). Payload sizes are seeded-deterministic: rANS realizes the
    # same stream byte for byte every run, zlib is looser across library
    # versions. Throughputs vary with the host -> informational.
    metrics = {
        "rans_vs_zlib_8bit": metric(ratio, tolerance=0.05),
    }
    if "ctx_vs_floor_8bit" in results:
        metrics["ctx_vs_floor_8bit"] = metric(results["ctx_vs_floor_8bit"],
                                              tolerance=0.05)
    _PAYLOAD_TOL = {"rans": 0.02, "rans-ctx": 0.02, "zlib": 0.05, "raw": 0.0}
    for p in results["points"]:
        point = f"{p['h']}x{p['w']}x{p['c']}_{p['bits']}b"
        metrics[f"entropy_floor_bpe.{point}"] = metric(
            p["entropy_floor_bpe"], tolerance=0.01)
        for b in backends:
            metrics[f"payload_bpe.{b}.{point}"] = metric(
                p[b]["payload_bpe"], tolerance=_PAYLOAD_TOL[b])
            metrics[f"decode_mb_s.{b}.{point}"] = metric(
                p[b]["decode_mb_s"], better="higher", tolerance=None)
    rec = bench_record(
        "codec",
        config={"seed": args.seed, "smoke": bool(args.smoke),
                "grid": [list(g) for g in grid]},
        metrics=metrics, raw=results)
    out = os.path.join(os.path.dirname(__file__), "BENCH_codec.json")
    write_bench(out, rec)
    print(f"wrote {out}")
    if args.smoke and not ok:
        print("ERROR: rANS payload gate failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
