"""Gateway serving benchmark: micro-batched vs one-at-a-time split inference.

    PYTHONPATH=src python benchmarks/serve_gateway.py [--smoke] [--requests N]

Measures the cloud side of the serving gateway (decode -> micro-batch ->
jitted BaF restore + fused consolidation -> cloud forward) under a stream of
single-image requests, for max_batch in {1, 4, 8}:

  * requests/sec end to end (encode + wire + cloud, wall clock),
  * requests/sec of the cloud compute alone (what batching actually targets),
  * p50/p99 total latency (simulated wire + measured compute).

Weights are untrained — throughput and compile behaviour do not depend on
training. Prints ``name,us_per_call,derived`` CSV rows like benchmarks/run.py
and writes benchmarks/serve_gateway_results.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.data.synthetic import shapes_batch_iterator
from repro.models.cnn import init_cnn
from repro.serve import (ChannelConfig, OperatingPoint, ServingGateway,
                         SimulatedChannel)

_ROWS: list[str] = []


def _row(name: str, us: float, derived: str):
    line = f"{name},{us:.1f},{derived}"
    _ROWS.append(line)
    print(line, flush=True)


def build_system(c: int = 8, input_size: int = 32):
    cnn_cfg = smoke_config()._replace(input_size=input_size)
    data_cfg = smoke_data_config()._replace(image_size=input_size,
                                            batch_size=8)
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    baf = init_baf_conv(jax.random.PRNGKey(1),
                        BaFConvConfig(c=c, q=cnn_cfg.split_q, hidden=8))
    bank = {c: (baf, np.arange(c))}
    return params, bank, data_cfg


def request_stream(data_cfg, n: int) -> np.ndarray:
    it = shapes_batch_iterator(data_cfg, seed=123)
    rows = []
    while len(rows) < n:
        img, _ = next(it)
        rows.append(np.asarray(img))
    return np.concatenate(rows, axis=0)[:n]


def bench_mode(params, bank, imgs, *, max_batch: int, c: int):
    op = OperatingPoint(c=c, bits=8)
    channel_cfg = ChannelConfig(bandwidth_bps=20e6, base_latency_s=0.005)
    gw = ServingGateway(params, bank, default_op=op, max_batch=max_batch,
                        channel=SimulatedChannel(channel_cfg))
    gw.serve(imgs[:max_batch * 2])                  # warm the jit caches
    # fresh channel for the measured run: the warm-up's wire backlog would
    # otherwise inflate latency proportionally to max_batch
    gw.channel = SimulatedChannel(channel_cfg)
    t0 = time.perf_counter()
    responses, tel = gw.serve(imgs)
    wall = time.perf_counter() - t0
    n = len(responses)
    # each batch's compute is stamped on every member; divide it back out
    cloud_s = sum(r.compute_s / r.batch_size for r in tel.records)
    s = tel.summary(wall_s=wall)
    return {
        "max_batch": max_batch,
        "requests": n,
        "wall_s": wall,
        "rps_end_to_end": n / wall,
        "rps_cloud_compute": n / cloud_s,
        "cloud_s": cloud_s,
        "p50_latency_ms": s["p50_latency_s"] * 1e3,
        "p99_latency_ms": s["p99_latency_s"] * 1e3,
        "mean_batch": s["mean_batch_size"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (< 60 s)")
    args = ap.parse_args()
    n = args.requests or (32 if args.smoke else 96)
    c = 8

    params, bank, data_cfg = build_system(c=c)
    imgs = request_stream(data_cfg, n)

    results = {}
    for max_batch in (1, 4, 8):
        r = bench_mode(params, bank, imgs, max_batch=max_batch, c=c)
        results[f"max_batch_{max_batch}"] = r
        _row(f"gateway_b{max_batch}", 1e6 / r["rps_end_to_end"],
             f"rps={r['rps_end_to_end']:.1f} "
             f"cloud_rps={r['rps_cloud_compute']:.1f} "
             f"p50={r['p50_latency_ms']:.2f}ms p99={r['p99_latency_ms']:.2f}ms")

    naive, b4, b8 = (results["max_batch_1"], results["max_batch_4"],
                     results["max_batch_8"])
    speed4 = b4["rps_cloud_compute"] / naive["rps_cloud_compute"]
    speed8 = b8["rps_cloud_compute"] / naive["rps_cloud_compute"]
    results["cloud_speedup_b4_vs_naive"] = speed4
    results["cloud_speedup_b8_vs_naive"] = speed8
    _row("gateway_speedup", 0.0,
         f"cloud-compute speedup b4={speed4:.2f}x b8={speed8:.2f}x vs naive")
    if speed4 <= 1.0:
        print("WARNING: micro-batching showed no cloud-compute win at "
              "batch=4 on this host", flush=True)

    out = os.path.join(os.path.dirname(__file__),
                       "serve_gateway_results.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
