"""Gateway serving benchmark: micro-batching + multi-tenant scheduling.

    PYTHONPATH=src python benchmarks/serve_gateway.py [--smoke] [--requests N]

Part 1 (single-tenant, as in PR 1) measures the cloud side of the serving
gateway (decode -> micro-batch -> jitted BaF restore + fused consolidation ->
cloud forward) under a stream of single-image requests, for max_batch in
{1, 4, 8}:

  * requests/sec end to end (encode + wire + cloud, wall clock),
  * requests/sec of the cloud compute alone (what batching actually targets),
  * p50/p99 total latency (simulated wire + measured compute).

Part 2 (multi-tenant, event-driven) sweeps the same total traffic over
1/4/16 tenants through MultiTenantGateway (DRR uplink scheduling + shared
bucket micro-batching) and reports aggregate cloud throughput, Jain
fairness over per-tenant wire bits, and each tenant's p99 vs its solo p99.
Acceptance gates (ISSUE 2): 16-tenant aggregate restore throughput within
20% of the single-tenant batched path; no tenant p99 above 3x its solo p99.

Part 3 (entropy-coded serving, ISSUE 3) runs the multi-tenant gateway end
to end with ``backend="rans"``: the rate controller selects operating
points from an RD table built from *actual encoded container bytes*
(cached on disk under benchmarks/, keyed by backend+seed, so CI reruns
skip the sweep), and the scheduler/channel meter every request at its true
container length. Reports per-backend mean wire bits and throughput, and
checks that scheduler grants exactly equal the containers' byte lengths.

Part 4 (batched decode, ISSUE 4) measures the plan API's vectorized host
decode: ``plan.decode_batch`` over 8 wire blobs vs 8 ``plan.decode`` calls,
asserting bit-identical outputs and >= 1.5x decode throughput at batch 8
(the acceptance gate, now for zlib AND the coalesced rANS batch decoder;
``--decode-only`` runs just this part for CI).

Part 5 (cloud executors + overload, ISSUE 5) swaps the cloud model under
the 16-tenant workload: a ``MultiQueueExecutor`` (4 queues) vs the default
``SerialExecutor`` on one deterministic ``LinearCostModel``, measuring
virtual-clock cloud throughput over a deep backlog (queue depth >= 4), and
a 2x-overload run through queue-depth admission measuring goodput of the
admitted requests vs a no-overload solo run. Acceptance gates: multi-queue
>= 1.8x serial throughput; goodput >= 0.9x solo; zero silent drops; and
bit-identical telemetry when the overload run repeats (deterministic
virtual-clock replay). ``--overload-only`` runs just this part for CI.

Part 6 (observability, ISSUE 6) reruns the 16-tenant overload workload with
the deterministic tracer + metrics registry attached and gates on: traced
telemetry bit-identical to untraced (the tracer only *reads* the virtual
clock), span sums reconciling to every request's ``total_latency_s`` within
1e-9 s, a valid Chrome/Perfetto trace export (written to
benchmarks/trace_gateway.json, metrics to trace_gateway.prom), byte-identical
trace JSON across two runs, and best-of-3 traced wall throughput >= 0.95x
untraced. ``--trace-only`` runs just this part for CI.

Weights are untrained — throughput and compile behaviour do not depend on
training. Prints ``name,us_per_call,derived`` CSV rows like benchmarks/run.py
and writes benchmarks/serve_gateway_results.json plus a schema'd
``BENCH_gateway*.json`` record (repro.obs.bench) for benchmarks/compare.py.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro import pipeline
from repro.obs import (MetricsRegistry, Tracer, hooks, reconcile_trace,
                       validate_chrome_trace)
from repro.obs.bench import bench_record, metric, write_bench
from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.data.synthetic import shapes_batch_iterator
from repro.models.cnn import init_cnn
from repro.serve import (ChannelConfig, LinearCostModel, MultiQueueExecutor,
                         MultiTenantGateway, OperatingPoint,
                         QueueDepthAdmission, RateController, RequestShed,
                         SerialExecutor, ServingGateway, SimulatedChannel,
                         TenantRequest, TenantSpec, build_rd_table,
                         load_or_build_rd_table, rd_grid)

_ROWS: list[str] = []


def _row(name: str, us: float, derived: str):
    line = f"{name},{us:.1f},{derived}"
    _ROWS.append(line)
    print(line, flush=True)


def build_system(c: int = 8, input_size: int = 32):
    cnn_cfg = smoke_config()._replace(input_size=input_size)
    data_cfg = smoke_data_config()._replace(image_size=input_size,
                                            batch_size=8)
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    baf = init_baf_conv(jax.random.PRNGKey(1),
                        BaFConvConfig(c=c, q=cnn_cfg.split_q, hidden=8))
    bank = {c: (baf, np.arange(c))}
    return params, bank, data_cfg


def request_stream(data_cfg, n: int) -> np.ndarray:
    it = shapes_batch_iterator(data_cfg, seed=123)
    rows = []
    while len(rows) < n:
        img, _ = next(it)
        rows.append(np.asarray(img))
    return np.concatenate(rows, axis=0)[:n]


def bench_mode(params, bank, imgs, *, max_batch: int, c: int):
    op = OperatingPoint(c=c, bits=8)
    channel_cfg = ChannelConfig(bandwidth_bps=20e6, base_latency_s=0.005)
    gw = ServingGateway(params, bank, default_op=op, max_batch=max_batch,
                        channel=SimulatedChannel(channel_cfg))
    gw.serve(imgs[:max_batch * 2])                  # warm the jit caches
    # fresh channel for the measured run: the warm-up's wire backlog would
    # otherwise inflate latency proportionally to max_batch
    gw.channel = SimulatedChannel(channel_cfg)
    t0 = time.perf_counter()
    responses, tel = gw.serve(imgs)
    wall = time.perf_counter() - t0
    n = len(responses)
    # each batch's compute is stamped on every member; divide it back out
    cloud_s = sum(r.compute_s / r.batch_size for r in tel.records)
    s = tel.summary(wall_s=wall)
    return {
        "max_batch": max_batch,
        "requests": n,
        "wall_s": wall,
        "rps_end_to_end": n / wall,
        "rps_cloud_compute": n / cloud_s,
        "cloud_s": cloud_s,
        "p50_latency_ms": s["p50_latency_s"] * 1e3,
        "p99_latency_ms": s["p99_latency_s"] * 1e3,
        "mean_batch": s["mean_batch_size"],
    }


def _tenant_workload(imgs, names, dt=0.0005):
    return [TenantRequest(tenant=names[i % len(names)], img=imgs[i],
                          t_submit=dt * i) for i in range(len(imgs))]


def _cloud_rps(tel, n):
    cloud_s = sum(r.compute_s / r.batch_size for r in tel.records)
    return n / cloud_s


def bench_tenants(params, bank, imgs, *, n_tenants: int, c: int,
                  max_batch: int = 8):
    """Same total traffic spread over ``n_tenants``; per-tenant p99 is also
    measured solo (tenant 0's slice alone) for the interference bound."""
    op = OperatingPoint(c=c, bits=8)
    names = [f"t{i}" for i in range(n_tenants)]

    def make_gateway(tenant_names):
        return MultiTenantGateway(
            params, bank,
            tenants=[TenantSpec(n) for n in tenant_names],
            channel_cfg=ChannelConfig(bandwidth_bps=20e6,
                                      base_latency_s=0.005),
            default_op=op, max_batch=max_batch,
            budget_bits_per_tick=None,    # uplink fabric not the bottleneck
            tick_s=0.01, batch_window_s=0.005)

    gw = make_gateway(names)
    work = _tenant_workload(imgs, names)
    # warm every bucket size the measured run can hit: bursts of 1/2/4/8
    # identical-op requests, spaced far beyond the batch window so each
    # burst flushes at exactly its own padded size
    warm, t = [], 0.0
    for burst in (1, 2, 4, 8):
        warm += [TenantRequest(names[0], imgs[i % len(imgs)], t)
                 for i in range(burst)]
        t += 1.0
    gw.serve_tenants(warm)
    t0 = time.perf_counter()
    _, tel = gw.serve_tenants(work)
    wall = time.perf_counter() - t0

    # solo baseline: tenant 0's slice, served alone on the same config
    solo_work = [TenantRequest("t0", w.img, w.t_submit)
                 for w in work if w.tenant == "t0"]
    solo_gw = make_gateway(["t0"])
    _, solo_tel = solo_gw.serve_tenants(solo_work)   # caches already warm
    solo_p99 = solo_tel.percentile("total_latency_s", 99, tenant="t0")

    per = tel.per_tenant()
    worst_p99 = max(ts["p99_latency_s"] for ts in per.values())
    return {
        "tenants": n_tenants,
        "requests": len(work),
        "wall_s": wall,
        "rps_cloud_compute": _cloud_rps(tel, len(work)),
        "fairness_bits": tel.fairness("bits_on_wire"),
        "worst_p99_ms": worst_p99 * 1e3,
        "solo_p99_ms": solo_p99 * 1e3,
        "p99_vs_solo": worst_p99 / max(solo_p99, 1e-9),
        "mean_batch": float(np.mean([r.batch_size for r in tel.records])),
    }


def bench_codec_backend(params, bank, imgs, *, backend: str, seed: int = 0,
                        n_requests: int = 12):
    """Part 3: multi-tenant serving with real entropy-coded accounting.

    The RD table is built at this backend's true container costs (and disk-
    cached keyed by backend+seed); channel + scheduler meter each request's
    actual serialized length.
    """
    bits_sweep = (4, 8)
    calib = imgs[:4]                 # key must match the slice actually used
    cache = os.path.join(os.path.dirname(__file__),
                         f"rd_cache_{backend.replace('-', '_')}_seed{seed}.json")
    # the cache key is the full operating-point grid plus the codec revision
    # (load_or_build_rd_table appends the revision itself): any change to the
    # grid, a backend's container format, or the wire profile rebuilds
    ops = rd_grid(bank, bits_sweep, backend)
    key = {"seed": seed, "calib": int(calib.shape[0]),
           "input": int(calib.shape[1])}
    table = load_or_build_rd_table(
        cache, key,
        lambda: build_rd_table(params, bank, calib, ops=ops), ops=ops)
    floor_db = float(np.median([p.psnr_db for p in table]))
    gw = MultiTenantGateway(
        params, bank,
        tenants=[TenantSpec("a"), TenantSpec("b", weight=2.0)],
        channel_cfg=ChannelConfig(bandwidth_bps=5e6, base_latency_s=0.005),
        controller=RateController(table, quality_floor_db=floor_db),
        backend=backend, max_batch=4,
        budget_bits_per_tick=400_000, tick_s=0.01, batch_window_s=0.005)
    work = [TenantRequest(tenant="ab"[i % 2], img=imgs[i % imgs.shape[0]],
                          t_submit=0.002 * i) for i in range(n_requests)]
    # warm every padded bucket size the measured run can hit (bursts spaced
    # far beyond the batch window flush at exactly their own size)
    warm, t = [], 0.0
    for burst in (1, 2, 4):
        warm += [TenantRequest("a", imgs[i % imgs.shape[0]], t)
                 for i in range(burst)]
        t += 1.0
    gw.serve_tenants(warm)
    t0 = time.perf_counter()
    _, tel = gw.serve_tenants(work)
    wall = time.perf_counter() - t0
    sched = gw.last_scheduler
    granted = {n: tq.granted_bits for n, tq in sched.tenants.items()}
    wire = {t: sum(r.bits_on_wire for r in tel.records if r.tenant == t)
            for t in granted}
    assert granted == wire, (
        f"scheduler grants {granted} != real container bits {wire}")
    s = tel.summary(wall_s=wall)
    return {
        "backend": backend,
        "requests": n_requests,
        "wall_s": wall,
        "rps_end_to_end": n_requests / wall,
        "mean_wire_bits": s["mean_bits_on_wire"],
        "p99_latency_ms": s["p99_latency_s"] * 1e3,
        "operating_points": [list(op) for op in s["operating_points"]],
        "accounting_exact": True,
    }


def bench_decode_batch(params, bank, imgs, *, c: int, bits: int = 6,
                       backend: str = "zlib", batch: int = 8,
                       reps: int = 40):
    """Part 4: batched vs per-request host decode (plan API).

    Encodes ``batch`` single-image requests at one operating point, then
    decodes them (a) one ``plan.decode`` per request and (b) one
    ``plan.decode_batch`` over all of them. Outputs must be bit-identical;
    the acceptance gate (ISSUE 4) requires the batched path to deliver
    >= 1.5x the per-request decode throughput at batch 8.
    """
    from repro.core.split import _jitted_cnn_fns

    edge, _ = _jitted_cnn_fns()
    baf, sel = bank[c]
    spec = pipeline.ModelSpec(sel_idx=np.asarray(sel), params=params,
                              baf_params=baf)
    op = pipeline.OperatingPoint(c=c, bits=bits, backend=backend)
    plan = pipeline.compile(op, spec)
    blobs = [plan.encode(edge(params, imgs[i % imgs.shape[0]][None]))
             for i in range(batch)]

    # correctness first: batched output rows must equal per-request decode
    per = [plan.decode(b) for b in blobs]
    bat = plan.decode_batch(blobs)
    assert np.array_equal(bat.codes,
                          np.concatenate([d.codes for d in per]))
    assert np.array_equal(bat.mins, np.concatenate([d.mins for d in per]))
    assert np.array_equal(bat.maxs, np.concatenate([d.maxs for d in per]))

    def time_loop(fn):
        best = float("inf")
        for _ in range(3):                       # best-of-3 rounds
            t0 = time.perf_counter()
            for _ in range(reps):
                fn()
            best = min(best, time.perf_counter() - t0)
        return best

    t_per = time_loop(lambda: [plan.decode(b) for b in blobs])
    t_bat = time_loop(lambda: plan.decode_batch(blobs))
    speedup = t_per / t_bat
    n = batch * reps
    return {
        "backend": backend, "bits": bits, "batch": batch,
        "per_request_rps": n / t_per,
        "batched_rps": n / t_bat,
        "speedup": speedup,
        "bit_identical": True,
    }


def bench_overload(params, bank, imgs, *, c: int, n_tenants: int = 16,
                   n_requests: int = 96, max_batch: int = 8,
                   n_queues: int = 4):
    """Part 5: multi-queue cloud executors + admission under overload.

    All runs share one deterministic LinearCostModel, so cloud throughput
    is a virtual-clock quantity (requests / executor makespan) that replays
    bit-identically — the real jitted compute still runs to produce logits,
    but its wall time never feeds the clock here.
    """
    op = OperatingPoint(c=c, bits=8)
    cost = LinearCostModel(base_s=0.004, per_item_s=0.001)
    names = [f"t{i}" for i in range(n_tenants)]

    def make(executor, admission=None):
        return MultiTenantGateway(
            params, bank, tenants=[TenantSpec(n) for n in names],
            channel_cfg=ChannelConfig(bandwidth_bps=50e6,
                                      base_latency_s=0.001),
            default_op=op, max_batch=max_batch,
            budget_bits_per_tick=None, tick_s=0.01, batch_window_s=0.002,
            executor=executor, admission=admission)

    def workload(n, dt):
        return [TenantRequest(names[i % n_tenants], imgs[i % len(imgs)],
                              t_submit=dt * i) for i in range(n)]

    def goodput(gw, tel):
        hist = gw.executor.history
        span = max(t.t_done for t in hist) - min(t.t_submit for t in hist)
        return len(tel) / span

    # warm every padded bucket size both executors can hit
    warm_gw = make(SerialExecutor(cost=cost))
    warm, t = [], 0.0
    for burst in (1, 2, 4, 8):
        warm += [TenantRequest(names[0], imgs[i % len(imgs)], t)
                 for i in range(burst)]
        t += 1.0
    warm_gw.serve_tenants(warm)

    # (a) deep backlog (offered >> capacity): virtual cloud throughput of
    # the multi-queue executor vs the serial baseline
    backlog = workload(n_requests, dt=0.0002)
    stats = {}
    for label, ex in (("serial", SerialExecutor(cost=cost)),
                      ("multi", MultiQueueExecutor(n_queues, cost=cost))):
        gw = make(ex)
        _, tel = gw.serve_tenants(backlog)
        assert len(tel) == len(backlog) and not tel.shed
        stats[label] = {"cloud_rps_virtual": goodput(gw, tel),
                        "max_queue_depth": ex.max_depth_seen}
    speedup = (stats["multi"]["cloud_rps_virtual"]
               / stats["serial"]["cloud_rps_virtual"])
    depth_ok = min(s["max_queue_depth"] for s in stats.values()) >= 4

    # (b) goodput under overload (offered ~1.8x the multi-queue cloud's
    # measured capacity) with queue-depth admission, vs a healthy solo run
    # at ~0.2x capacity. The solo run carries the SAME admission policy:
    # zero sheds there proves the baseline load sits below the
    # admission-controlled capacity (a baseline without admission could
    # never shed, which would make the check vacuous)
    admission_for = lambda: QueueDepthAdmission(max_depth=n_queues)  # noqa: E731
    solo_gw = make(MultiQueueExecutor(n_queues, cost=cost),
                   admission=admission_for())
    _, solo_tel = solo_gw.serve_tenants(workload(n_requests, dt=0.002))
    assert not solo_tel.shed, (
        f"the baseline run shed {len(solo_tel.shed)} requests — it is not "
        f"a no-overload baseline")
    solo_goodput = goodput(solo_gw, solo_tel)

    def overload_run():
        # depth limit = one batch per queue: brown-out kicks in as soon as
        # the cloud is saturated, which a 2x offered load guarantees
        gw = make(MultiQueueExecutor(n_queues, cost=cost),
                  admission=admission_for())
        out, tel = gw.serve_tenants(workload(n_requests, dt=0.00025))
        return gw, out, tel

    gw2, out2, tel2 = overload_run()
    served = sum(not isinstance(r, RequestShed)
                 for rs in out2.values() for r in rs)
    assert served + len(tel2.shed) == n_requests, "silent drop detected"
    assert served == len(tel2)
    over_goodput = goodput(gw2, tel2)
    # efficiency floor: the baseline above is arrival-rate-limited, so the
    # 0.9x-of-solo gate alone would tolerate a large goodput collapse.
    # Admitted traffic must also flow within 25% of the saturated cloud's
    # own throughput (part (a)'s deep-backlog measurement) — shedding costs
    # some batch fill, but a queue-selection or admission bug serializing
    # the cloud fails this hard. All virtual-clock quantities: the ratio
    # is deterministic, not host noise.
    goodput_vs_capacity = over_goodput / stats["multi"]["cloud_rps_virtual"]

    # deterministic virtual-clock replay: repeat the overload run and
    # require bit-identical telemetry (served records AND the shed series)
    _, _, tel3 = overload_run()
    replay_ok = (tel2.records == tel3.records and tel2.shed == tel3.shed)

    return {
        "tenants": n_tenants, "requests": n_requests, "queues": n_queues,
        "serial_cloud_rps_virtual": stats["serial"]["cloud_rps_virtual"],
        "multi_cloud_rps_virtual": stats["multi"]["cloud_rps_virtual"],
        "multi_vs_serial": speedup,
        "max_queue_depth_serial": stats["serial"]["max_queue_depth"],
        "max_queue_depth_multi": stats["multi"]["max_queue_depth"],
        "depth_ok": depth_ok,
        "solo_goodput_rps": solo_goodput,
        "overload_goodput_rps": over_goodput,
        "goodput_vs_solo": over_goodput / solo_goodput,
        "goodput_vs_capacity": goodput_vs_capacity,
        "overload_shed": len(tel2.shed),
        "overload_shed_rate": tel2.shed_rate(),
        "zero_silent_drops": True,
        "replay_bit_identical": replay_ok,
    }


def run_overload_part(params, bank, imgs, *, c: int, n_requests: int):
    r = bench_overload(params, bank, imgs, c=c, n_requests=n_requests)
    _row("gateway_overload", 0.0,
         f"multi/serial={r['multi_vs_serial']:.2f}x "
         f"(serial {r['serial_cloud_rps_virtual']:.0f} -> multi "
         f"{r['multi_cloud_rps_virtual']:.0f} virtual rps, depth >= "
         f"{min(r['max_queue_depth_serial'], r['max_queue_depth_multi'])}) "
         f"goodput@2x={r['goodput_vs_solo']:.2f}x solo "
         f"({r['goodput_vs_capacity']:.2f}x saturated capacity) "
         f"shed={r['overload_shed']} ({100 * r['overload_shed_rate']:.0f}%) "
         f"replay={'bit-identical' if r['replay_bit_identical'] else 'FAIL'}")
    assert r["depth_ok"], (
        "ACCEPTANCE FAIL: backlog never reached queue depth 4 — the "
        "overload workload is not overloading")
    assert r["multi_vs_serial"] >= 1.8, (
        f"ACCEPTANCE FAIL: MultiQueueExecutor {r['multi_vs_serial']:.2f}x "
        f"serial cloud throughput is below the 1.8x gate")
    assert r["goodput_vs_solo"] >= 0.9, (
        f"ACCEPTANCE FAIL: goodput under 2x offered load is "
        f"{r['goodput_vs_solo']:.2f}x solo, below the 0.9x gate")
    assert r["goodput_vs_capacity"] >= 0.75, (
        f"ACCEPTANCE FAIL: admitted goodput under overload is only "
        f"{r['goodput_vs_capacity']:.2f}x the saturated cloud throughput "
        f"(floor 0.75x) — goodput collapsed under shedding")
    assert r["replay_bit_identical"], (
        "ACCEPTANCE FAIL: overload run did not replay bit-identically")
    return r


def bench_trace(params, bank, imgs, *, c: int, n_tenants: int = 16,
                n_requests: int = 64, n_queues: int = 4, trials: int = 5):
    """Part 6: tracing overhead + trace validity on the overload workload.

    Every virtual-clock quantity is tracing-invariant by construction (the
    tracer only *reads* event times already computed by the gateway), so the
    traced run's telemetry must equal the untraced run's bit for bit. The
    overhead gate is therefore purely wall-clock: the traced side must
    deliver >= 0.95x the untraced throughput under the noise-robust ratio
    estimate below.
    """
    op = OperatingPoint(c=c, bits=8)
    cost = LinearCostModel(base_s=0.004, per_item_s=0.001)
    names = [f"t{i}" for i in range(n_tenants)]

    def make(tracer=None, metrics=None):
        return MultiTenantGateway(
            params, bank, tenants=[TenantSpec(n) for n in names],
            channel_cfg=ChannelConfig(bandwidth_bps=50e6,
                                      base_latency_s=0.001),
            default_op=op, max_batch=8,
            budget_bits_per_tick=None, tick_s=0.01, batch_window_s=0.002,
            executor=MultiQueueExecutor(n_queues, cost=cost),
            admission=QueueDepthAdmission(max_depth=n_queues),
            tracer=tracer, metrics=metrics)

    work = [TenantRequest(names[i % n_tenants], imgs[i % len(imgs)],
                          t_submit=0.00025 * i) for i in range(n_requests)]
    warm, t = [], 0.0                       # warm every padded bucket size
    for burst in (1, 2, 4, 8):
        warm += [TenantRequest(names[0], imgs[i % len(imgs)], t)
                 for i in range(burst)]
        t += 1.0
    make().serve_tenants(warm)

    def run(traced: bool):
        registry = MetricsRegistry() if traced else None
        gw = make(tracer=Tracer() if traced else None, metrics=registry)
        if traced:
            with hooks.active(registry):
                t0 = time.perf_counter()
                _, tel = gw.serve_tenants(work)
                wall = time.perf_counter() - t0
        else:
            t0 = time.perf_counter()
            _, tel = gw.serve_tenants(work)
            wall = time.perf_counter() - t0
        return wall, tel, gw.tracer, registry

    # interleave off/on trials: host drift (thermal, page cache, sibling
    # jobs) then hits both sides equally instead of biasing whichever
    # block ran second; best-of-N on each side finishes the job
    walls_off, walls_on, traces = [], [], []
    tel_off = tel_on = tracer = registry = None
    for _ in range(trials):
        w, tel_off, _, _ = run(traced=False)
        walls_off.append(w)
        w, tel_on, tracer, registry = run(traced=True)
        walls_on.append(w)
        traces.append(tracer.to_json())

    # the tracer must be an observer, never an actor, on the virtual clock
    invariant = (tel_on.records == tel_off.records
                 and tel_on.shed == tel_off.shed)
    deterministic = all(tj == traces[0] for tj in traces)
    tracer.validate()
    n_events = validate_chrome_trace(tracer.to_chrome())
    reconcile_err = reconcile_trace(tracer, tel_on)

    here = os.path.dirname(__file__)
    trace_path = os.path.join(here, "trace_gateway.json")
    tracer.save(trace_path)
    with open(os.path.join(here, "trace_gateway.prom"), "w") as f:
        f.write(registry.to_prometheus_text())

    # two noise-robust estimators of the same ~100 ms quantity: min/min
    # estimates the noise-free floor of each side, the median of adjacent
    # off/on pair ratios cancels drift common to a pair. Host noise on a
    # shared runner depresses either one spuriously; a genuine tracing
    # overhead depresses both, so gate on the more favorable.
    pair_ratios = sorted(o / n for o, n in zip(walls_off, walls_on))
    throughput_ratio = max(min(walls_off) / min(walls_on),
                           pair_ratios[len(pair_ratios) // 2])
    return {
        "tenants": n_tenants, "requests": n_requests, "trials": trials,
        "served": len(tel_on), "shed": len(tel_on.shed),
        "spans": len(tracer.spans), "instants": len(tracer.instants),
        "chrome_events": n_events,
        "reconcile_err_s": reconcile_err,
        "wall_untraced_s": min(walls_off),
        "wall_traced_s": min(walls_on),
        "traced_throughput_ratio": throughput_ratio,
        "telemetry_invariant": invariant,
        "trace_deterministic": deterministic,
        "metric_series": len(registry),
        "trace_path": trace_path,
    }


def run_trace_part(params, bank, imgs, *, c: int, n_requests: int):
    r = bench_trace(params, bank, imgs, c=c, n_requests=n_requests)
    _row("gateway_trace", 0.0,
         f"spans={r['spans']} events={r['chrome_events']} "
         f"reconcile_err={r['reconcile_err_s']:.2e}s "
         f"traced/untraced={r['traced_throughput_ratio']:.3f}x "
         f"telemetry={'invariant' if r['telemetry_invariant'] else 'FAIL'} "
         f"replay={'byte-identical' if r['trace_deterministic'] else 'FAIL'} "
         f"series={r['metric_series']}")
    assert r["telemetry_invariant"], (
        "ACCEPTANCE FAIL: tracing perturbed the virtual clock — traced "
        "telemetry differs from untraced")
    assert r["trace_deterministic"], (
        "ACCEPTANCE FAIL: trace JSON not byte-identical across runs")
    assert r["reconcile_err_s"] < 1e-9, (
        f"ACCEPTANCE FAIL: span sums reconcile to telemetry within "
        f"{r['reconcile_err_s']:.2e}s, gate is 1e-9s")
    assert r["traced_throughput_ratio"] >= 0.95, (
        f"ACCEPTANCE FAIL: traced run delivers only "
        f"{r['traced_throughput_ratio']:.3f}x untraced throughput "
        f"(gate 0.95x)")
    return r


def _gateway_bench_metrics(results: dict) -> dict:
    """Trajectory metrics from whichever parts ran. Virtual-clock ratios are
    deterministic (tight tolerance); wall-clock rates are informational."""
    m: dict = {}
    if "overload" in results:
        o = results["overload"]
        m["overload.multi_vs_serial"] = metric(
            o["multi_vs_serial"], better="higher", tolerance=0.1)
        m["overload.goodput_vs_solo"] = metric(
            o["goodput_vs_solo"], better="higher", tolerance=0.1)
        m["overload.goodput_vs_capacity"] = metric(
            o["goodput_vs_capacity"], better="higher", tolerance=0.1)
        m["overload.shed_rate"] = metric(
            o["overload_shed_rate"], tolerance=0.1)
    for key, r in results.items():
        if key.startswith("decode_batch_"):
            m[f"{key}.speedup"] = metric(r["speedup"], better="higher",
                                         tolerance=None)
        if key.startswith("codec_") and isinstance(r, dict) \
                and "mean_wire_bits" in r:
            m[f"{key}.mean_wire_bits"] = metric(r["mean_wire_bits"],
                                                tolerance=0.02)
        if key.startswith("tenants_"):
            m[f"{key}.fairness_bits"] = metric(
                r["fairness_bits"], better="higher", tolerance=0.05)
            m[f"{key}.cloud_rps"] = metric(
                r["rps_cloud_compute"], better="higher", tolerance=None)
    if "trace" in results:
        tr = results["trace"]
        m["trace.spans"] = metric(tr["spans"], tolerance=0.0)
        m["trace.chrome_events"] = metric(tr["chrome_events"], tolerance=0.0)
        # zero baseline -> compare.py checks |current| against the tolerance
        # absolutely: any reconcile error above the 1e-9 gate fails
        m["trace.reconcile_err_s"] = metric(tr["reconcile_err_s"],
                                            tolerance=1e-9)
        m["trace.throughput_ratio"] = metric(
            tr["traced_throughput_ratio"], better="higher", tolerance=None)
    for key in ("cloud_speedup_b4_vs_naive", "cloud_speedup_b8_vs_naive",
                "throughput_16v1"):
        if key in results:
            m[key] = metric(results[key], better="higher", tolerance=None)
    return m


def _write_gateway_bench(results: dict, args, *, suffix: str = ""):
    rec = bench_record(
        f"gateway{suffix}",
        config={"smoke": bool(args.smoke), "requests": args.requests,
                "part": suffix.lstrip("_") or "all"},
        metrics=_gateway_bench_metrics(results),
        raw={k: v for k, v in results.items() if k != "trace_path"})
    out = os.path.join(os.path.dirname(__file__),
                       f"BENCH_gateway{suffix}.json")
    write_bench(out, rec)
    print(f"wrote {out}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (< 60 s)")
    ap.add_argument("--decode-only", action="store_true",
                    help="run only part 4 (batched decode gate, < 60 s)")
    ap.add_argument("--overload-only", action="store_true",
                    help="run only part 5 (executor/overload gates, < 60 s)")
    ap.add_argument("--trace-only", action="store_true",
                    help="run only part 6 (tracing overhead gate, < 60 s)")
    args = ap.parse_args()
    n = args.requests or (32 if args.smoke else 96)
    c = 8

    params, bank, data_cfg = build_system(c=c)
    imgs = request_stream(data_cfg, n)

    if args.overload_only:
        r = run_overload_part(params, bank, imgs, c=c,
                              n_requests=64 if args.smoke else 96)
        _write_gateway_bench({"overload": r}, args, suffix="_overload")
        print("overload gates OK")
        return

    if args.trace_only:
        r = run_trace_part(params, bank, imgs, c=c,
                           n_requests=48 if args.smoke else 64)
        _write_gateway_bench({"trace": r}, args, suffix="_trace")
        print("trace gates OK")
        return

    if args.decode_only:
        # both backends carry the 1.5x gate now: zlib via unpack_bits_batch,
        # rans via the chunk-level cross-container interleave (codec/batch.py)
        decode_results = {}
        for backend in ("zlib", "rans"):
            r = bench_decode_batch(params, bank, imgs, c=c, backend=backend)
            _row(f"gateway_decode_batch_{backend}", 1e6 / r["batched_rps"],
                 f"per_req_rps={r['per_request_rps']:.0f} "
                 f"batched_rps={r['batched_rps']:.0f} "
                 f"speedup={r['speedup']:.2f}x bit_identical=True")
            assert r["speedup"] >= 1.5, (
                f"ACCEPTANCE FAIL: {backend} decode_batch speedup "
                f"{r['speedup']:.2f}x below the 1.5x gate")
            decode_results[f"decode_batch_{backend}"] = r
        _write_gateway_bench(decode_results, args, suffix="_decode")
        print("decode gate OK")
        return

    results = {}
    for max_batch in (1, 4, 8):
        r = bench_mode(params, bank, imgs, max_batch=max_batch, c=c)
        results[f"max_batch_{max_batch}"] = r
        _row(f"gateway_b{max_batch}", 1e6 / r["rps_end_to_end"],
             f"rps={r['rps_end_to_end']:.1f} "
             f"cloud_rps={r['rps_cloud_compute']:.1f} "
             f"p50={r['p50_latency_ms']:.2f}ms p99={r['p99_latency_ms']:.2f}ms")

    naive, b4, b8 = (results["max_batch_1"], results["max_batch_4"],
                     results["max_batch_8"])
    speed4 = b4["rps_cloud_compute"] / naive["rps_cloud_compute"]
    speed8 = b8["rps_cloud_compute"] / naive["rps_cloud_compute"]
    results["cloud_speedup_b4_vs_naive"] = speed4
    results["cloud_speedup_b8_vs_naive"] = speed8
    _row("gateway_speedup", 0.0,
         f"cloud-compute speedup b4={speed4:.2f}x b8={speed8:.2f}x vs naive")
    if speed4 <= 1.0:
        print("WARNING: micro-batching showed no cloud-compute win at "
              "batch=4 on this host", flush=True)

    # -- part 2: multi-tenant sweep (event-driven gateway) ------------------
    for n_tenants in (1, 4, 16):
        r = bench_tenants(params, bank, imgs, n_tenants=n_tenants, c=c)
        results[f"tenants_{n_tenants}"] = r
        _row(f"gateway_t{n_tenants}", 1e6 * r["wall_s"] / r["requests"],
             f"cloud_rps={r['rps_cloud_compute']:.1f} "
             f"fairness={r['fairness_bits']:.3f} "
             f"worst_p99={r['worst_p99_ms']:.2f}ms "
             f"(solo {r['solo_p99_ms']:.2f}ms, "
             f"x{r['p99_vs_solo']:.2f}) mean_batch={r['mean_batch']:.2f}")

    # -- part 3: entropy-coded serving (true container-byte accounting) -----
    bank_multi = dict(bank)
    if 4 not in bank_multi:      # a second C so the RD table has real choice
        baf4 = init_baf_conv(jax.random.PRNGKey(2),
                             BaFConvConfig(c=4, q=smoke_config().split_q,
                                           hidden=8))
        bank_multi[4] = (baf4, np.arange(4))
    for backend in ("zlib", "rans"):
        r = bench_codec_backend(params, bank_multi, imgs, backend=backend,
                                n_requests=8 if args.smoke else 24)
        results[f"codec_{backend}"] = r
        _row(f"gateway_codec_{backend}", 1e6 * r["wall_s"] / r["requests"],
             f"rps={r['rps_end_to_end']:.1f} "
             f"mean_wire_bits={r['mean_wire_bits']:.0f} "
             f"p99={r['p99_latency_ms']:.2f}ms ops={r['operating_points']} "
             f"accounting=exact")

    # -- part 4: batched host decode (plan API, ISSUE 4 gate) ---------------
    for backend in ("zlib", "rans"):
        r = bench_decode_batch(params, bank_multi, imgs, c=c, backend=backend)
        results[f"decode_batch_{backend}"] = r
        _row(f"gateway_decode_batch_{backend}", 1e6 / r["batched_rps"],
             f"per_req_rps={r['per_request_rps']:.0f} "
             f"batched_rps={r['batched_rps']:.0f} "
             f"speedup={r['speedup']:.2f}x bit_identical=True")
    for backend in ("zlib", "rans"):
        dec = results[f"decode_batch_{backend}"]
        assert dec["speedup"] >= 1.5, (
            f"ACCEPTANCE FAIL: {backend} decode_batch speedup "
            f"{dec['speedup']:.2f}x at batch {dec['batch']} is below the "
            f"1.5x gate")
        _row(f"gateway_decode_gate_{backend}", 0.0,
             f"decode_batch {dec['speedup']:.2f}x >= 1.5x at batch "
             f"{dec['batch']}: OK")

    # -- part 5: cloud executors + overload shedding (ISSUE 5 gates) --------
    results["overload"] = run_overload_part(
        params, bank, imgs, c=c, n_requests=64 if args.smoke else 96)

    # -- part 6: tracing overhead + trace validity (ISSUE 6 gates) ----------
    results["trace"] = run_trace_part(
        params, bank, imgs, c=c, n_requests=48 if args.smoke else 64)

    t1, t16 = results["tenants_1"], results["tenants_16"]
    tp_ratio = t16["rps_cloud_compute"] / t1["rps_cloud_compute"]
    results["throughput_16v1"] = tp_ratio
    ok_tp = tp_ratio >= 0.8
    ok_p99 = all(results[f"tenants_{n}"]["p99_vs_solo"] <= 3.0
                 for n in (1, 4, 16))
    results["acceptance_throughput"] = ok_tp
    results["acceptance_p99"] = ok_p99
    _row("gateway_tenancy_check", 0.0,
         f"16-tenant/1-tenant cloud throughput {tp_ratio:.2f} "
         f"({'OK' if ok_tp else 'FAIL'} >= 0.8); p99 <= 3x solo: "
         f"{'OK' if ok_p99 else 'FAIL'}")
    if not (ok_tp and ok_p99):
        print("WARNING: multi-tenant acceptance gate failed on this host",
              flush=True)

    out = os.path.join(os.path.dirname(__file__),
                       "serve_gateway_results.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out}")
    _write_gateway_bench(results, args)


if __name__ == "__main__":
    main()
