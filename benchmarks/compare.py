"""Benchmark-trajectory gate: compare a BENCH_*.json against a baseline.

    PYTHONPATH=src python benchmarks/compare.py \
        --current benchmarks/BENCH_codec.json \
        --baseline /tmp/BENCH_codec.baseline.json [--check] [--verbose]

Loads two ``repro-bench/1`` records (see ``repro.obs.bench``) and prints a
per-metric trajectory report. Exit code 1 when any gated metric regressed
beyond the **baseline's** tolerance, a gated metric disappeared, the names
differ, or the configs drifted (``--allow-config-drift`` downgrades drift to
informational — e.g. intentionally comparing across request counts).

``--check FILE`` just validates a record against the schema and exits.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

from repro.obs.bench import compare, format_report, load_bench  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="gate a benchmark record against a baseline")
    ap.add_argument("--current", help="BENCH_*.json from this run")
    ap.add_argument("--baseline", help="BENCH_*.json to gate against")
    ap.add_argument("--check", metavar="FILE",
                    help="only validate FILE against the schema")
    ap.add_argument("--allow-config-drift", action="store_true",
                    help="report config differences instead of failing")
    ap.add_argument("--quiet", action="store_true",
                    help="print only non-passing lines + the summary")
    args = ap.parse_args(argv)

    if args.check:
        rec = load_bench(args.check)          # raises on schema violations
        n_gated = sum(m.get("tolerance") is not None
                      for m in rec["metrics"].values())
        print(f"{args.check}: valid {rec['schema']} record "
              f"'{rec['name']}' ({len(rec['metrics'])} metrics, "
              f"{n_gated} gated)")
        return 0

    if not (args.current and args.baseline):
        ap.error("--current and --baseline are required (or use --check)")
    current = load_bench(args.current)
    baseline = load_bench(args.baseline)
    ok, deltas = compare(current, baseline,
                         allow_config_drift=args.allow_config_drift)
    print(f"comparing '{current['name']}' "
          f"{baseline['git_sha'][:12]} -> {current['git_sha'][:12]}")
    print(format_report(deltas, verbose=not args.quiet))
    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
