"""Streaming session benchmark: temporal P-frame compression + lossy recovery.

    PYTHONPATH=src python benchmarks/session_bench.py [--smoke]

Part 1 (temporal coding) streams synthetic correlated camera frames through
the real edge network and the session codec twice — temporal (I+P) and
forced I-only — and measures wire bits. Both paths must decode to
*bit-identical* quantized codes (matched restore quality by construction;
the comparison is wire bits at equal output). Acceptance gates (ISSUE 8):

  * mean P-frame wire bits <= 0.7x mean I-frame wire bits,
  * whole-session I-only bits / (I+P) bits >= 1.4x.

Part 2 (lossy streaming) drives concurrent sessions through a
MultiTenantGateway via SessionManager over seeded 5%-loss channels with
corruption and reorder, on a deterministic LinearCostModel. Gates:

  * every session ends in sync (SessionManager.run asserts it),
  * max desync-to-resync recovery <= 2x the analytic single-cycle bound
    (recovery_bound_s; the 2x absorbs loss-chained NACK cycles at 5%),
  * a second run is bit-identical (StreamReport.signature equality) — the
    full loss + reorder + NACK + QoS pipeline replays deterministically.

Part 3 (QoS) repeats the workload under a tight admission policy and
reports degrade-before-shed behaviour: ladder step-downs happen (and are
metered separately from sheds), and no frame is shed above the floor rung.

Prints ``name,us_per_call,derived`` CSV rows like the other benchmarks and
writes a schema'd BENCH_session.json (repro.obs.bench) for compare.py.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.configs.yolo_baf import smoke_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.data.synthetic import correlated_frames
from repro.models.cnn import init_cnn
from repro.obs.bench import bench_record, metric, write_bench
from repro.pipeline import Capabilities, OperatingPoint
from repro.serve import (ChannelConfig, LinearCostModel, MultiQueueExecutor,
                         MultiTenantGateway, QueueDepthAdmission, TenantSpec)
from repro.session import (QosLevel, SessionConfig, SessionDecoder,
                           SessionEncoder, SessionManager, SessionSpec)
from repro.session.recovery import RecoveryConfig, recovery_bound_s

C = 8
OP = OperatingPoint(c=C, bits=6, backend="rans")
LADDER = (QosLevel(OP),
          QosLevel(OperatingPoint(c=C, bits=4, backend="rans"),
                   keyframe_interval=8),
          QosLevel(OperatingPoint(c=4, bits=4, backend="rans"),
                   keyframe_interval=8, frame_stride=2))
FPS = 20.0

_ROWS: list[str] = []


def _row(name: str, us: float, derived: str):
    line = f"{name},{us:.1f},{derived}"
    _ROWS.append(line)
    print(line, flush=True)


# Fixed-camera clip parameters: sub-pixel jitter (drift * SIZE ~ 0.13 px per
# frame) plus mild sensor noise. Whole-pixel motion decorrelates the conv
# latent badly (no motion compensation in the codec — see docs/STREAMING.md),
# so this is the workload temporal delta coding is built for; SIZE=64 keeps
# the latent large enough that per-frame container overhead is amortized.
SIZE = 64
DRIFT = 0.002
NOISE = 0.003


def _clip(n_frames: int, seed: int) -> np.ndarray:
    return correlated_frames(n_frames, image_size=SIZE, drift=DRIFT,
                             noise=NOISE, seed=seed)


def build_system(input_size: int = SIZE):
    cnn_cfg = smoke_config()._replace(input_size=input_size)
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    bank = {c: (init_baf_conv(jax.random.PRNGKey(c),
                              BaFConvConfig(c=c, q=cnn_cfg.split_q,
                                            hidden=8)),
                np.arange(c)) for c in (4, C)}
    return params, bank


def mk_gateway(params, bank, *, n_sessions, admission=None, cost=None):
    tenants = [TenantSpec(name=f"cam{i}", priority=i % 2)
               for i in range(n_sessions)]
    return MultiTenantGateway(
        params, bank, tenants=tenants,
        executor=MultiQueueExecutor(
            2, cost=cost or LinearCostModel(0.002, 0.0005)),
        admission=admission, max_batch=8, batch_window_s=0.01)


# ---------------------------------------------------------------------------
# Part 1: temporal coding vs I-only at matched restore quality
# ---------------------------------------------------------------------------

def bench_temporal_coding(params, bank, gw, *, n_frames: int) -> dict:
    clip = _clip(n_frames, seed=77)
    cfg = SessionConfig(session_id=0, levels=(gw._fit_op(OP),))
    enc = SessionEncoder(cfg, gw.plan_for)
    enc_ionly = SessionEncoder(
        SessionConfig(session_id=0, levels=(gw._fit_op(OP),)), gw.plan_for,
        capabilities=Capabilities(session_profiles=(), downgrade=True))
    dec = SessionDecoder(cfg, gw.plan_for)
    i_bits, p_bits, ionly_bits = [], [], []
    t0 = time.perf_counter()
    for idx in range(n_frames):
        z = gw._edge_fn(gw.params, np.asarray(clip[idx])[None])
        blob, meta = enc.encode(z)
        blob_i, meta_i = enc_ionly.encode(z)
        assert meta_i.intra
        (i_bits if meta.intra else p_bits).append(meta.wire_bits)
        ionly_bits.append(meta_i.wire_bits)
        # matched restore quality: both paths must reconstruct the exact
        # same quantized codes (temporal prediction is lossless)
        decoded, _ = dec.decode(blob)
        dec_i = SessionDecoder(cfg, gw.plan_for)
        decoded_i, _ = dec_i.decode(blob_i)
        assert np.array_equal(decoded.codes, decoded_i.codes), idx
    wall = time.perf_counter() - t0
    p_over_i = float(np.mean(p_bits) / np.mean(i_bits))
    reduction = float(sum(ionly_bits) / (sum(i_bits) + sum(p_bits)))
    _row("session_temporal", 1e6 * wall / n_frames,
         f"p_over_i={p_over_i:.3f} reduction_vs_ionly={reduction:.2f}x "
         f"n_p={len(p_bits)}")
    assert p_over_i <= 0.7, (
        f"ACCEPTANCE FAIL: P-frame wire bits {p_over_i:.3f}x of I-frame, "
        f"above the 0.7x gate")
    assert reduction >= 1.4, (
        f"ACCEPTANCE FAIL: session wire-bit reduction {reduction:.2f}x vs "
        f"I-only, below the 1.4x gate")
    return {"p_over_i_wire_ratio": p_over_i,
            "reduction_vs_ionly": reduction,
            "i_frame_bits_mean": float(np.mean(i_bits)),
            "p_frame_bits_mean": float(np.mean(p_bits)),
            "frames": n_frames}


# ---------------------------------------------------------------------------
# Part 2: lossy streaming — bounded recovery + deterministic replay
# ---------------------------------------------------------------------------

def bench_lossy_streaming(params, bank, *, n_sessions: int,
                          n_frames: int) -> dict:
    gw = mk_gateway(params, bank, n_sessions=n_sessions)
    sessions = [SessionSpec(name=f"cam{i}", fps=FPS, start_s=0.002 * i)
                for i in range(n_sessions)]
    mgr = SessionManager(
        gw, sessions, ladder=LADDER,
        channel_cfg=ChannelConfig(bandwidth_bps=20e6, base_latency_s=0.005,
                                  loss_p=0.05, corrupt_p=0.02,
                                  reorder_p=0.02, reorder_delay_s=0.01,
                                  mtu_bytes=256),
        recovery=RecoveryConfig(nack_latency_s=0.01), seed=3)
    frames = {f"cam{i}": _clip(n_frames, seed=10 + i)
              for i in range(n_sessions)}
    t0 = time.perf_counter()
    _, report = mgr.run(frames)          # asserts every session ends in sync
    wall = time.perf_counter() - t0
    _, report2 = mgr.run(frames)
    replay_ok = report.signature() == report2.signature()

    total = n_sessions * n_frames
    outcomes: dict[str, int] = {}
    for name in frames:
        for k, v in report.counts(name).items():
            outcomes[k] = outcomes.get(k, 0) + v
    bound = recovery_bound_s(fps=FPS, uplink_latency_s=0.02,
                             nack_latency_s=0.01, margin_frames=2)
    max_rec = max(r.max_recovery_s for r in report.recovery.values())
    episodes = sum(r.episodes for r in report.recovery.values())
    nacks = sum(report.nacks.values())
    _row("session_lossy", 1e6 * wall / total,
         f"sessions={n_sessions} outcomes={outcomes} episodes={episodes} "
         f"nacks={nacks} max_recovery={max_rec * 1e3:.1f}ms "
         f"bound={bound * 1e3:.0f}ms replay={replay_ok}")
    assert outcomes.get("lost", 0) + outcomes.get("corrupt", 0) > 0, (
        "ACCEPTANCE FAIL: seeded lossy run exercised no impairment")
    assert max_rec <= 2 * bound, (
        f"ACCEPTANCE FAIL: recovery {max_rec:.3f}s exceeds 2x analytic "
        f"bound {bound:.3f}s")
    assert replay_ok, "ACCEPTANCE FAIL: lossy streaming replay diverged"
    return {"sessions": n_sessions, "frames_per_session": n_frames,
            "outcomes": outcomes, "desync_episodes": episodes,
            "nacks": nacks, "max_recovery_s": max_rec,
            "recovery_bound_s": bound,
            "served_fraction": outcomes.get("served", 0) / total,
            "replay_bit_identical": replay_ok, "wall_s": wall}


# ---------------------------------------------------------------------------
# Part 3: QoS — degrade before shed under pressure
# ---------------------------------------------------------------------------

def bench_qos_degrade(params, bank, *, n_sessions: int,
                      n_frames: int) -> dict:
    # a deliberately slow cloud (batches cost >> the 50 ms frame interval)
    # so the executor backlog trips the depth-1 admission gate and forces
    # the manager down the QoS ladder
    gw = mk_gateway(params, bank, n_sessions=n_sessions,
                    admission=QueueDepthAdmission(1),
                    cost=LinearCostModel(0.12, 0.01))
    sessions = [SessionSpec(name=f"cam{i}", fps=FPS, start_s=0.001 * i)
                for i in range(n_sessions)]
    mgr = SessionManager(
        gw, sessions, ladder=LADDER,
        channel_cfg=ChannelConfig(bandwidth_bps=20e6, base_latency_s=0.005),
        recovery=RecoveryConfig(nack_latency_s=0.01), seed=5)
    frames = {f"cam{i}": _clip(n_frames, seed=30 + i)
              for i in range(n_sessions)}
    _, report = mgr.run(frames)
    tel = report.telemetry
    floor = len(LADDER) - 1
    shed_above_floor = sum(
        1 for name in frames for f in report.frames[name]
        if f.outcome == "shed" and f.level < floor)
    degraded = len(tel.degraded)
    _row("session_qos", 0.0,
         f"degraded={degraded} shed={len(tel.shed)} served={len(tel)} "
         f"shed_above_floor={shed_above_floor}")
    assert degraded > 0, (
        "ACCEPTANCE FAIL: pressure run triggered no QoS degradation")
    assert shed_above_floor == 0, (
        f"ACCEPTANCE FAIL: {shed_above_floor} frames shed above the ladder "
        f"floor — degrade-before-shed violated")
    return {"degraded": degraded, "shed": len(tel.shed), "served": len(tel),
            "degrade_by_tenant": tel.degrade_by_tenant()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (< 60 s)")
    args = ap.parse_args()
    n_sessions = 4 if args.smoke else 12
    n_frames = 24 if args.smoke else 60

    params, bank = build_system()
    gw = mk_gateway(params, bank, n_sessions=1)

    temporal = bench_temporal_coding(params, bank, gw,
                                     n_frames=n_frames)
    lossy = bench_lossy_streaming(params, bank, n_sessions=n_sessions,
                                  n_frames=n_frames)
    qos = bench_qos_degrade(params, bank, n_sessions=n_sessions,
                            n_frames=max(8, n_frames // 2))

    rec = bench_record(
        "session",
        config={"smoke": bool(args.smoke), "sessions": n_sessions,
                "frames": n_frames, "image_size": SIZE, "drift": DRIFT,
                "noise": NOISE},
        metrics={
            # trajectory gates: seeded + virtual-clocked, so these are
            # deterministic across runs of one commit
            "p_over_i_wire_ratio": metric(
                temporal["p_over_i_wire_ratio"], better="lower",
                tolerance=0.05),
            "reduction_vs_ionly": metric(
                temporal["reduction_vs_ionly"], better="higher",
                tolerance=0.05),
            "max_recovery_vs_bound": metric(
                lossy["max_recovery_s"] / lossy["recovery_bound_s"],
                better="lower", tolerance=0.25),
            "served_fraction_at_5pct_loss": metric(
                lossy["served_fraction"], better="higher", tolerance=0.1),
            "desync_episodes": metric(
                lossy["desync_episodes"], better="lower", tolerance=0.5),
            # wall time is runner-dependent: informational only
            "lossy_wall_s": metric(lossy["wall_s"], better="lower",
                                   tolerance=None),
        },
        raw={"temporal": temporal, "lossy": lossy, "qos": qos})
    out = os.path.join(os.path.dirname(__file__), "BENCH_session.json")
    write_bench(out, rec)
    print(f"wrote {out}")
    print("session gates OK")


if __name__ == "__main__":
    main()
