"""Sharded cloud tier benchmark: gateway federation on a MeshExecutor.

    PYTHONPATH=src python benchmarks/mesh_bench.py [--smoke]

Forces an 8-device host mesh (XLA_FLAGS, set before jax imports) and runs a
federated multi-gateway workload — smoke: 2 gateways x 32 tenants (64
tenants total), full: 4 gateways x 64 tenants (256 tenants) — through the
same shared cloud executor twice:

  serial : SerialExecutor, the single-core cloud of previous releases
  mesh   : MeshExecutor over make_dev_mesh(prefer="data") — batched decode
           on the host, restore + cloud forward under shard_map with
           batch-axis data parallelism

Both runs price virtual service time with ONE frozen CalibratedCostModel,
fit from measured warm compute on the serial tier (least squares over
(padded_size, wall_s) samples, seeded from the launch/hlo_cost roofline).
The mesh executor evaluates the same model at its per-shard row count, so
the speedup is the cost model's own prediction of data parallelism — and
because the model is frozen, every run replays bit for bit.

Acceptance gates (ISSUE 7):
  * calibration: fitted per-item cost within 25% of measured wall
    (mean relative error over the warm samples),
  * mesh logits bit-identical to serial, per tenant, per request,
  * mesh replay bit-identical (logits + telemetry),
  * mesh virtual-cloud throughput >= 1.8x serial at 64+ federated tenants,
  * overload: per-gateway admission on the shared mesh — every submission
    ends as exactly one response or one explicit shed, never silent.

Writes a schema'd BENCH_mesh.json (repro.obs.bench) for compare.py.
"""
from __future__ import annotations

import argparse
import math
import os
import time

_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = f"{os.environ.get('XLA_FLAGS', '')} {_FLAG}".strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np

from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.data.synthetic import shapes_batch_iterator
from repro.launch.mesh import make_dev_mesh
from repro.models.cnn import init_cnn
from repro.obs.bench import bench_record, metric, write_bench
from repro.serve import (CalibratedCostModel, ChannelConfig,
                         GatewayFederation, MeshExecutor, MultiTenantGateway,
                         OperatingPoint, QueueDepthAdmission, SerialExecutor,
                         TenantRequest, TenantSpec, seed_cost_from_hlo)

C = 8
OP = OperatingPoint(c=C, bits=8)
BUCKET = 64
# backlogged uplink: arrivals must not dominate the executor makespan, or
# the rps ratio measures the wire, not the mesh
CHANNEL = ChannelConfig(bandwidth_bps=1e9, base_latency_s=1e-3)

_ROWS: list[str] = []


def _row(name: str, us: float, derived: str):
    line = f"{name},{us:.1f},{derived}"
    _ROWS.append(line)
    print(line, flush=True)


def build_system(input_size: int = 32):
    cnn_cfg = smoke_config()._replace(input_size=input_size)
    data_cfg = smoke_data_config()._replace(image_size=input_size,
                                            batch_size=8)
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    baf = init_baf_conv(jax.random.PRNGKey(1),
                        BaFConvConfig(c=C, q=cnn_cfg.split_q, hidden=8))
    return params, {C: (baf, np.arange(C))}, data_cfg


def image_pool(data_cfg, n: int = 16) -> np.ndarray:
    it = shapes_batch_iterator(data_cfg, seed=123)
    rows = []
    while len(rows) < n:
        img, _ = next(it)
        rows.append(np.asarray(img))
    return np.concatenate(rows, axis=0)[:n]


def mk_gateway(system, executor, *, seed, n_tenants, max_batch=BUCKET,
               admission=None, batch_window_s=None):
    params, bank, _ = system
    tenants = [TenantSpec(name=f"g{seed}t{i}") for i in range(n_tenants)]
    return MultiTenantGateway(params, bank, tenants=tenants, default_op=OP,
                              channel_cfg=CHANNEL, max_batch=max_batch,
                              batch_window_s=batch_window_s,
                              executor=executor, shared_executor=True,
                              seed=seed, admission=admission)


def workload(gw, imgs, per_tenant: int, *, dt=1e-5, t0=0.0):
    """Round-robin over the gateway's tenants, backlogged (dt apart)."""
    names = sorted(gw.specs)
    reqs = []
    for r in range(per_tenant):
        for i, name in enumerate(names):
            k = r * len(names) + i
            reqs.append(TenantRequest(tenant=name,
                                      img=imgs[k % len(imgs)][None],
                                      t_submit=t0 + k * dt))
    return reqs


# ---------------------------------------------------------------------------
# calibration: measure warm serial compute, fit, freeze
# ---------------------------------------------------------------------------

def calibrate(system, imgs) -> CalibratedCostModel:
    """Warm the serial tier across every bucket size, then fit an affine
    cost from warm (padded_size, wall_s) samples; seeded from the
    launch/hlo_cost roofline so even a degenerate sample set has a slope."""
    params, bank, _ = system
    warm_ex = SerialExecutor()                       # MeasuredCost
    gw = mk_gateway(system, warm_ex, seed=0, n_tenants=1,
                    batch_window_s=0.005)
    sizes = [1, 2, 4, 8, 16, 32, 64]
    bursts = []
    t = 0.0
    for s in sizes:                                   # one bucket per burst
        for i in range(s):
            bursts.append(TenantRequest(tenant="g0t0",
                                        img=imgs[i % len(imgs)][None],
                                        t_submit=t + i * 1e-5))
        t += 1.0
    gw.serve_tenants(bursts)                          # compile pass

    plan = gw.plan_for(gw.default_op)
    codes_hw = plan.decode_batch(
        [gw.encode_request(imgs[0][None])[1]]).codes.shape[1:3]
    calib = seed_cost_from_hlo(plan, (BUCKET, *codes_hw, C))
    _row("hlo_roofline_seed", calib.seed_per_item_s * 1e6, "us_per_item")

    warm_ex.cost = calib                              # warm measured passes
    for _ in range(3):                                # 3x per size: average
        gw.serve_tenants(bursts)                      # out host timing noise
    calib.freeze()
    _row("calibrated_base", calib.base_s * 1e6, "us")
    _row("calibrated_per_item", calib.per_item_s * 1e6, "us")
    rel_err = calib.fit_rel_err()
    _row("calibration_fit_rel_err", rel_err * 1e6, f"{rel_err:.3f}")
    assert rel_err < 0.25, (
        f"ACCEPTANCE FAIL: calibrated cost {rel_err:.1%} off measured wall "
        f"(gate < 25%) over {len(calib.samples)} samples")
    return calib


# ---------------------------------------------------------------------------
# federated runs
# ---------------------------------------------------------------------------

def virtual_rps(executor, n_served: int) -> float:
    hist = executor.history
    span = max(t.t_done for t in hist) - min(t.t_start for t in hist)
    return n_served / span


def logit_rows(results):
    return [{t: [np.asarray(r.logits) for r in rs]
             for t, rs in out.items()} for out, _ in results]


def run_federation(system, imgs, executor, *, n_gateways, n_tenants,
                   per_tenant):
    gws = [mk_gateway(system, executor, seed=g, n_tenants=n_tenants)
           for g in range(n_gateways)]
    fed = GatewayFederation(gws)
    wls = [workload(gw, imgs, per_tenant) for gw in gws]
    t0 = time.perf_counter()
    results = fed.serve(wls)
    wall = time.perf_counter() - t0
    n = sum(len(w) for w in wls)
    assert all(not tel.shed for _, tel in results)
    return fed, wls, results, virtual_rps(executor, n), wall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: 2 gateways x 32 tenants")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    assert n_dev == 8, f"expected the forced 8-device host mesh, got {n_dev}"
    n_gateways, n_tenants = (2, 32) if args.smoke else (4, 64)
    per_tenant = 2 * BUCKET // n_tenants              # 2 full buckets/gateway
    n_requests = n_gateways * n_tenants * per_tenant
    print(f"mesh_bench: {n_gateways} gateways x {n_tenants} tenants x "
          f"{per_tenant} reqs = {n_requests} requests on {n_dev} devices",
          flush=True)

    system = build_system()
    imgs = image_pool(system[2])
    calib = calibrate(system, imgs)

    # -- serial baseline ----------------------------------------------------
    ser_ex = SerialExecutor(cost=calib)
    _, _, ser_results, ser_rps, ser_wall = run_federation(
        system, imgs, ser_ex, n_gateways=n_gateways, n_tenants=n_tenants,
        per_tenant=per_tenant)
    _row("serial_virtual_rps", 1e6 / ser_rps, f"{ser_rps:.0f}_rps")

    # -- mesh ---------------------------------------------------------------
    mesh_ex = MeshExecutor(make_dev_mesh(prefer="data"), cost=calib)
    fed_m, wls_m, mesh_results, mesh_rps, mesh_wall = run_federation(
        system, imgs, mesh_ex, n_gateways=n_gateways, n_tenants=n_tenants,
        per_tenant=per_tenant)
    _row("mesh_virtual_rps", 1e6 / mesh_rps, f"{mesh_rps:.0f}_rps")

    speedup = mesh_rps / ser_rps
    _row("mesh_speedup", speedup * 1e6, f"{speedup:.2f}x")
    assert speedup >= 1.8, (
        f"ACCEPTANCE FAIL: mesh {speedup:.2f}x serial virtual-cloud rps "
        f"at {n_gateways * n_tenants} tenants (gate >= 1.8x)")

    # -- bit-identity: mesh == serial, per tenant, per request --------------
    for gs, gm in zip(logit_rows(ser_results), logit_rows(mesh_results)):
        assert gs.keys() == gm.keys()
        for t in gs:
            assert len(gs[t]) == per_tenant
            for a, b in zip(gs[t], gm[t]):
                assert np.array_equal(a, b), (
                    f"ACCEPTANCE FAIL: tenant {t} mesh logits != serial")
    print("mesh logits bit-identical to serial: ok", flush=True)

    # -- deterministic replay under the frozen cost model -------------------
    replay = fed_m.serve(wls_m)
    for (o1, t1), (o2, t2) in zip(mesh_results, replay):
        assert t1.records == t2.records, "ACCEPTANCE FAIL: replay telemetry"
        r1, r2 = logit_rows([(o1, t1)])[0], logit_rows([(o2, t2)])[0]
        for t in r1:
            for a, b in zip(r1[t], r2[t]):
                assert np.array_equal(a, b), (
                    "ACCEPTANCE FAIL: replay logits drifted")
    print("mesh replay bit-identical: ok", flush=True)

    # -- overload: per-gateway admission against the shared mesh ------------
    # a bursty gateway fills the shared executor; a depth-limited gateway
    # sheds its own overflow while the burst gateway rides through
    over_ex = MeshExecutor(make_dev_mesh(prefer="data"), cost=calib)
    gw_burst = mk_gateway(system, over_ex, seed=0, n_tenants=4, max_batch=8)
    gw_lim = mk_gateway(system, over_ex, seed=1, n_tenants=4, max_batch=8,
                        admission=QueueDepthAdmission(1))
    wl_burst = workload(gw_burst, imgs, 8, dt=1e-4)
    wl_lim = workload(gw_lim, imgs, 8, dt=1e-4, t0=0.003)
    (out_b, tel_b), (out_l, tel_l) = GatewayFederation(
        [gw_burst, gw_lim]).serve([wl_burst, wl_lim])
    served = sum(len(t) for t in (tel_b, tel_l))
    shed = len(tel_b.shed) + len(tel_l.shed)
    assert served + shed == len(wl_burst) + len(wl_lim), (
        "ACCEPTANCE FAIL: silent drop under overload")
    assert not tel_b.shed, "burst gateway has no admission policy"
    assert tel_l.shed, ("expected the depth-limited gateway to shed against "
                        "the shared-mesh backlog")
    _row("overload_shed", shed * 1e6, f"{shed}_of_{len(wl_lim)}")
    print(f"overload: {served} served + {shed} shed, zero silent drops",
          flush=True)

    # -- record -------------------------------------------------------------
    rec = bench_record(
        "mesh_bench",
        config={"smoke": bool(args.smoke), "devices": n_dev,
                "gateways": n_gateways, "tenants_per_gateway": n_tenants,
                "per_tenant": per_tenant, "bucket": BUCKET, "c": C,
                "bits": 8},
        metrics={
            # the calibrated coefficients are measured, so run-to-run ratios
            # wobble; the hard >= 1.8x gate lives in this script, the
            # trajectory gate only catches collapses
            "mesh_speedup": metric(speedup, better="higher", tolerance=0.5),
            "mesh_virtual_rps": metric(mesh_rps, better="higher"),
            "serial_virtual_rps": metric(ser_rps, better="higher"),
            "calibration_fit_rel_err": metric(calib.fit_rel_err(),
                                              better="lower"),
            "calibrated_per_item_us": metric(calib.per_item_s * 1e6,
                                             better="lower"),
            "serial_wall_s": metric(ser_wall, better="lower"),
            "mesh_wall_s": metric(mesh_wall, better="lower"),
            "overload_shed": metric(shed, better="lower"),
        },
        raw={"rows": _ROWS})
    out = os.path.join(os.path.dirname(__file__), "BENCH_mesh.json")
    write_bench(out, rec)
    print(f"wrote {out}", flush=True)
    print("mesh_bench: all acceptance gates passed", flush=True)


if __name__ == "__main__":
    main()
