"""Roofline analysis — derive the three terms per (arch × shape) cell from the
dry-run's compiled artifact (EXPERIMENTS.md §Roofline).

    compute    = HLO_FLOPs_per_device / peak_FLOP/s          (197 TF bf16, v5e)
    memory     = HLO_bytes_per_device / HBM_bw               (819 GB/s)
    collective = collective_bytes_per_device / ICI_link_bw   (~50 GB/s/link)

Note on "per chips": XLA's cost_analysis runs on the SPMD-*partitioned*
module, i.e. what ONE chip executes — so dividing by per-chip peaks is the
same as the brief's HLO_total/(chips × peak) under perfect balance. The
collective term uses summed collective operand bytes from the partitioned HLO
(dryrun.collective_bytes); it is an upper-ish bound that ignores ring-step
overlap, good for *ranking* bottlenecks and tracking deltas.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --single-pod-only --json dryrun.json
    PYTHONPATH=src python -m benchmarks.roofline --json dryrun.json --md roofline.md

Also writes benchmarks/BENCH_roofline.json — a schema'd ``repro-bench/1``
record with one informational metric per (arch, shape, kind) cell, so
``benchmarks/compare.py`` can report roofline trajectory across commits
(cost-model quantities, never gated).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

from repro.obs.bench import bench_record, metric, write_bench  # noqa: E402

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link

# steps per "unit of work" for MODEL_FLOPS accounting
_FWD_BWD = {"train": 6.0, "prefill": 2.0, "decode": 2.0, "long": 2.0}


def model_flops(arch: str, shape: str, kind: str, chips: int) -> float:
    """Analytic useful FLOPs per device: k·N_active·D_tokens / chips."""
    from repro.configs import get_config
    from repro.configs.base import SHAPES, active_param_count
    cfg = get_config(arch)
    sh = SHAPES[shape]
    n = active_param_count(cfg)
    if kind in ("train", "prefill", "long"):
        tokens = sh["global_batch"] * sh["seq_len"]
    else:                      # decode: one new token per sequence
        tokens = sh["global_batch"]
    return _FWD_BWD[kind] * n * tokens / chips


def analyse(rec: dict, chips: int = 256) -> dict:
    """rec: one dry-run record (repro.launch.dryrun.run_cell output).

    Prefers the trip-count-aware *_scaled fields (repro.launch.hlo_cost);
    falls back to raw cost_analysis values for old records."""
    flops = rec.get("flops_scaled") or rec.get("flops") or 0.0
    nbytes = rec.get("bytes_scaled") or rec.get("bytes_accessed") or 0.0
    coll = sum((rec.get("collective_bytes_scaled")
                or rec.get("collective_bytes") or {}).values())
    t_c = flops / PEAK_FLOPS
    t_m = nbytes / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"], rec.get("kind", "train"), chips)
    bound = max(terms.values())
    return {
        **{f"t_{k}": v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": (mf / flops) if flops else 0.0,
        "roofline_frac": (mf / PEAK_FLOPS) / bound if bound else 0.0,
        # fraction of the bound step time that is useful model math at peak:
        # = (what an ideal implementation would take) / (this one's bound)
    }


_SUGGEST = {
    "compute": "reduce recompute (remat policy) / raise useful_ratio toward 1",
    "memory": "fuse elementwise chains, widen microbatch to raise arithmetic "
              "intensity, keep weights resident (serve: tp sharding)",
    "collective": "reshard to cut per-layer all-gathers, overlap collectives "
                  "with compute, compress cross-pod traffic (grad_compress)",
}


def to_markdown(records: list[dict], chips: int = 256) -> str:
    lines = [
        "| arch | shape | kind | compute s | memory s | collective s | "
        "dominant | useful ratio | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        if rec.get("status") == "skip":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | — | "
                         f"N/A (quadratic attn @500k) | — | — | — |")
            continue
        if rec.get("status") != "ok":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | FAIL | | | "
                         f"| | | {rec.get('error','')[:60]} |")
            continue
        a = analyse(rec, chips)
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec.get('kind','')} "
            f"| {a['t_compute']:.3e} | {a['t_memory']:.3e} | {a['t_collective']:.3e} "
            f"| **{a['dominant']}** | {a['useful_ratio']:.2f} "
            f"| {a['roofline_frac']:.3f} | {_SUGGEST[a['dominant']]} |")
    return "\n".join(lines)


def bench_metrics(records: list[dict], chips: int = 256) -> dict:
    """Informational trajectory metrics: the cost model ranks bottlenecks,
    it does not gate (tolerance None everywhere)."""
    out: dict = {}
    for rec in records:
        if rec.get("status") != "ok":
            continue
        a = analyse(rec, chips)
        cell = f"{rec['arch']}.{rec['shape']}.{rec.get('kind', 'train')}"
        out[f"{cell}.bound_s"] = metric(
            max(a["t_compute"], a["t_memory"], a["t_collective"]),
            tolerance=None)
        out[f"{cell}.useful_ratio"] = metric(a["useful_ratio"],
                                             better="higher", tolerance=None)
        out[f"{cell}.roofline_frac"] = metric(a["roofline_frac"],
                                              better="higher", tolerance=None)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", required=True, help="dry-run records")
    ap.add_argument("--md", default=None, help="write markdown table here")
    ap.add_argument("--chips", type=int, default=256)
    args = ap.parse_args(argv)
    records = json.load(open(args.json))
    records = [r for r in records if r.get("mesh") != "pod2x16x16"
               or r.get("status") == "skip"]
    md = to_markdown(records, args.chips)
    if args.md:
        with open(args.md, "w") as f:
            f.write(md + "\n")
        print(f"wrote {args.md}")
    else:
        print(md)
    rec = bench_record(
        "roofline",
        config={"chips": args.chips, "cells": len(records)},
        metrics=bench_metrics(records, args.chips))
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "BENCH_roofline.json")
    write_bench(out, rec)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
