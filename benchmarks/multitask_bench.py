"""Multi-task serving benchmark: one encoded stream, N downstream heads.

    PYTHONPATH=src python benchmarks/multitask_bench.py [--smoke]

Part 1 sweeps per-task RD tables (tasks/distortion.py): every operating
point is encoded/decoded/restored once and every registered head prices it
by its own output divergence. The sweep is disk-cached
(rd_cache_tasks_seed5.json, keyed on the ops grid + codec revision + head
set + weight vector) so CI reruns are cheap.

Part 2 (the headline gate) compares ONE shared stream against per-task
independent streams at matched per-task distortion: floors are anchored at
a common operating point (quality at the anchor minus a margin), so the
shared selection meets every floor without degradation, and every
independent single-task selection meets the same floor. Gates:

  * >= 3 heads served from the single stream, no floor degraded,
  * independent-streams total wire bits >= 1.5x the shared stream's.

Part 3 drives the MultiTaskGateway end to end with a mixed tenant
population (one full-set tenant, one classify-only tenant) on a
deterministic LinearCostModel. Gates:

  * single-decode fan-out: no head runs more often than batches are
    decoded, and all >= 3 heads are served,
  * the declared-subset tenant pays measurably fewer wire bits than the
    full-stream tenant at equal request counts (<= 0.8x),
  * a second run of the same workload replays bit-identically.

Prints ``name,us_per_call,derived`` CSV rows like the other benchmarks and
writes a schema'd BENCH_multitask.json (repro.obs.bench) for compare.py.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.data.synthetic import shapes_batch_iterator
from repro.models.cnn import init_cnn
from repro.obs.bench import bench_record, metric, write_bench
from repro.pipeline import OperatingPoint
from repro.serve import LinearCostModel, SerialExecutor, TenantRequest, TenantSpec
from repro.tasks import (BitAllocationController, HeadConfig,
                         MultiTaskGateway, build_task_rd_tables,
                         init_head_bank, load_or_build_task_tables,
                         task_set_key)

SIZE = 32
CALIB_N = 4
SEED = 5
OPS = tuple(OperatingPoint(c=c, bits=b, backend="rans")
            for c in (4, 8) for b in (2, 4, 6, 8))
# weight = how much a tenant cares; detect is the premium task, embed is
# best-effort — also the degrade order under pressure (lowest first)
WEIGHTS = {"classify": 1.0, "detect": 3.0, "embed": 0.5}
# floors anchor: every task's floor is its measured quality at this op
# minus a margin, so the anchor op provably meets every floor and the
# shared-vs-independent comparison runs in the non-degraded regime
ANCHOR = OperatingPoint(c=8, bits=6, backend="rans")
FLOOR_MARGIN_DB = 0.5

_ROWS: list[str] = []


def _row(name: str, us: float, derived: str):
    line = f"{name},{us:.1f},{derived}"
    _ROWS.append(line)
    print(line, flush=True)


def build_system():
    cnn_cfg = smoke_config()._replace(input_size=SIZE)
    data_cfg = smoke_data_config()._replace(image_size=SIZE,
                                            batch_size=max(CALIB_N, 8))
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    bank = {c: (init_baf_conv(jax.random.PRNGKey(c),
                              BaFConvConfig(c=c, q=cnn_cfg.split_q,
                                            hidden=8)),
                np.arange(c)) for c in (4, 8)}
    imgs, _ = next(shapes_batch_iterator(data_cfg, seed=SEED))
    head_cfg = HeadConfig(split_p=cnn_cfg.split_p,
                          num_classes=cnn_cfg.num_classes)
    head_bank = init_head_bank(jax.random.PRNGKey(99), head_cfg)
    return params, bank, np.asarray(imgs), head_cfg, head_bank


# ---------------------------------------------------------------------------
# Part 1: per-task RD tables (cached sweep)
# ---------------------------------------------------------------------------

def sweep_tables(params, bank, imgs, head_cfg, head_bank) -> dict:
    cache = os.path.join(os.path.dirname(__file__),
                         f"rd_cache_tasks_seed{SEED}.json")
    t0 = time.perf_counter()
    tables = load_or_build_task_tables(
        cache,
        {"seed": SEED, "image_size": SIZE, "n_calib": CALIB_N,
         "head_seed": 99, "anchor": str(ANCHOR)},
        lambda: build_task_rd_tables(params, bank, imgs[:CALIB_N],
                                     head_bank=head_bank, head_cfg=head_cfg,
                                     ops=OPS),
        ops=OPS, tasks=task_set_key(head_bank, WEIGHTS))
    wall = time.perf_counter() - t0
    _row("multitask_tables", 1e6 * wall / (len(OPS) * len(tables)),
         f"tasks={sorted(tables)} ops={len(OPS)} wall={wall:.2f}s")
    return tables


def anchored_floors(tables: dict) -> dict:
    anchor = ANCHOR.resolve()
    floors = {}
    for task, pts in tables.items():
        at = next(p for p in pts if p.op.resolve() == anchor)
        floors[task] = at.psnr_db - FLOOR_MARGIN_DB
    return floors


# ---------------------------------------------------------------------------
# Part 2: shared stream vs independent per-task streams
# ---------------------------------------------------------------------------

def bench_shared_vs_independent(alloc: BitAllocationController) -> dict:
    tasks = alloc.tasks
    shared = alloc.select(tasks)
    independent = alloc.independent_bits(tasks)
    ratio = independent / shared.bits_per_example
    _row("multitask_allocation", 0.0,
         f"heads={len(tasks)} shared_bits={shared.bits_per_example:.0f} "
         f"independent_bits={independent:.0f} ratio={ratio:.2f}x "
         f"op={shared.op.c}c{shared.op.bits}b degraded={shared.degraded}")
    assert len(tasks) >= 3, (
        f"ACCEPTANCE FAIL: only {len(tasks)} heads priced, need >= 3")
    assert shared.degraded == (), (
        f"ACCEPTANCE FAIL: anchored floors must not degrade, got "
        f"{shared.degraded}")
    for task in tasks:                  # matched per-task distortion
        assert shared.quality_db(task) >= alloc.floor(task), task
    assert ratio >= 1.5, (
        f"ACCEPTANCE FAIL: independent streams only {ratio:.2f}x the shared "
        f"stream's bits, below the 1.5x gate")
    return {"heads": list(tasks),
            "shared_bits_per_example": shared.bits_per_example,
            "independent_bits_total": independent,
            "independent_over_shared": ratio,
            "shared_op": f"c{shared.op.c}_b{shared.op.bits}",
            "per_task_quality_db": dict(shared.per_task_quality_db),
            "floors_db": {t: alloc.floor(t) for t in tasks}}


# ---------------------------------------------------------------------------
# Part 3: gateway fan-out, subset billing, replay
# ---------------------------------------------------------------------------

def bench_gateway_fanout(params, bank, imgs, head_cfg, head_bank,
                         alloc: BitAllocationController,
                         *, n_requests: int) -> dict:
    def run():
        gw = MultiTaskGateway(
            params, bank,
            tenants=[TenantSpec("full"),
                     TenantSpec("lite", tasks=("classify",))],
            head_bank=head_bank, head_cfg=head_cfg, allocator=alloc,
            executor=SerialExecutor(cost=LinearCostModel(0.004, 0.001)),
            max_batch=4, batch_window_s=0.01)
        work = [TenantRequest(("full", "lite")[i % 2], imgs[i % len(imgs)],
                              t_submit=0.002 * i) for i in range(n_requests)]
        t0 = time.perf_counter()
        responses, tel = gw.serve_tenants(work)
        return gw, responses, tel, time.perf_counter() - t0

    gw, responses, tel, wall = run()
    per = tel.per_tenant()
    subset_fraction = (per["lite"]["bits_on_wire"]
                       / per["full"]["bits_on_wire"])
    heads_served = sorted(gw.head_calls)
    max_head_over_decode = max(gw.head_calls.values()) / gw.decode_calls
    _row("multitask_gateway", 1e6 * wall / n_requests,
         f"requests={n_requests} decodes={gw.decode_calls} "
         f"head_calls={gw.head_calls} subset_bits={subset_fraction:.2f}x")
    assert len(heads_served) >= 3, (
        f"ACCEPTANCE FAIL: only heads {heads_served} served")
    assert max_head_over_decode <= 1.0, (
        f"ACCEPTANCE FAIL: a head ran {max_head_over_decode:.2f}x per "
        f"decoded batch — single-decode fan-out violated")
    assert per["full"]["count"] == per["lite"]["count"]
    assert subset_fraction <= 0.8, (
        f"ACCEPTANCE FAIL: classify-only tenant pays {subset_fraction:.2f}x "
        f"of the full tenant's wire bits, above the 0.8x gate")

    gw2, responses2, tel2, _ = run()
    replay_ok = tel2.per_tenant() == per
    for tenant in responses:
        for a, b in zip(responses[tenant], responses2[tenant]):
            replay_ok &= a.tasks == b.tasks and all(
                np.array_equal(a.outputs[t], b.outputs[t])
                for t in a.outputs)
    _row("multitask_replay", 0.0, f"replay={replay_ok}")
    assert replay_ok, "ACCEPTANCE FAIL: multi-task replay diverged"
    return {"requests": n_requests, "decode_calls": gw.decode_calls,
            "head_calls": dict(sorted(gw.head_calls.items())),
            "heads_served": heads_served,
            "subset_bits_fraction": subset_fraction,
            "full_bits_on_wire": per["full"]["bits_on_wire"],
            "lite_bits_on_wire": per["lite"]["bits_on_wire"],
            "replay_bit_identical": replay_ok, "wall_s": wall}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (< 60 s)")
    args = ap.parse_args()
    n_requests = 16 if args.smoke else 48

    params, bank, imgs, head_cfg, head_bank = build_system()
    tables = sweep_tables(params, bank, imgs, head_cfg, head_bank)
    alloc = BitAllocationController(tables, weights=WEIGHTS,
                                    floors=anchored_floors(tables))
    shared = bench_shared_vs_independent(alloc)
    fanout = bench_gateway_fanout(params, bank, imgs, head_cfg, head_bank,
                                  alloc, n_requests=n_requests)

    rec = bench_record(
        "multitask",
        config={"smoke": bool(args.smoke), "image_size": SIZE,
                "n_calib": CALIB_N, "seed": SEED, "ops": len(OPS),
                "weights": WEIGHTS, "anchor": str(ANCHOR),
                "floor_margin_db": FLOOR_MARGIN_DB,
                "n_requests": n_requests},
        metrics={
            # deterministic: seeded data, virtual-clock gateway, cached
            # (or deterministically rebuilt) RD sweep
            "independent_over_shared_bits": metric(
                shared["independent_over_shared"], better="higher",
                tolerance=0.05),
            "shared_bits_per_example": metric(
                shared["shared_bits_per_example"], better="lower",
                tolerance=0.05),
            "subset_bits_fraction": metric(
                fanout["subset_bits_fraction"], better="lower",
                tolerance=0.05),
            "heads_per_decode": metric(
                sum(fanout["head_calls"].values())
                / fanout["decode_calls"], better="higher", tolerance=0.1),
            # wall time is runner-dependent: informational only
            "gateway_wall_s": metric(fanout["wall_s"], better="lower",
                                     tolerance=None),
        },
        raw={"shared_vs_independent": shared, "gateway": fanout})
    out = os.path.join(os.path.dirname(__file__), "BENCH_multitask.json")
    write_bench(out, rec)
    print(f"wrote {out}")
    print("multitask gates OK")


if __name__ == "__main__":
    main()
