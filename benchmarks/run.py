"""Benchmark harness — one function per paper table/figure.

  bench_channel_sweep   Fig. 3  (accuracy vs C, n=8)
  bench_bit_sweep       Fig. 4  (accuracy + wire bits vs n, C=P/4)
  bench_codec           Fig. 4  codec comparison (raw / tile+zlib / entropy
                                floor / all-channels-8bit baseline of [4])
  bench_consolidation   eq. (6) on/off ablation
  bench_kernels         hot-path µs/call + bandwidth-model sanity

Prints ``name,us_per_call,derived`` CSV rows (assignment contract) and writes
benchmarks/results.json for EXPERIMENTS.md. Scale knobs via env:
  BENCH_FAST=1        fewer training steps (CI-speed)
"""
from __future__ import annotations

import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

FAST = os.environ.get("BENCH_FAST", "0") == "1"
RESULTS: dict = {}
_ROWS: list[str] = []


def _row(name: str, us: float, derived: str):
    line = f"{name},{us:.1f},{derived}"
    _ROWS.append(line)
    print(line, flush=True)


def _timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


# ---------------------------------------------------------------------------
# Shared Tier-A setup: pretrained reduced CNN + channel order (computed once)
# ---------------------------------------------------------------------------

_SYSTEM = None


def tier_a_system():
    global _SYSTEM
    if _SYSTEM is not None:
        return _SYSTEM
    from repro.configs.yolo_baf import smoke_config, smoke_data_config
    from repro.train.baf_trainer import compute_channel_order, eval_cnn, pretrain_cnn
    cnn_cfg = smoke_config()._replace(input_size=64)
    data_cfg = smoke_data_config()._replace(image_size=64, batch_size=16)
    steps = 120 if FAST else 800
    t0 = time.time()
    params, _ = pretrain_cnn(cnn_cfg, data_cfg, steps=steps, verbose=False)
    cloud_acc = eval_cnn(params, data_cfg, batches=10 if FAST else 25)
    order = compute_channel_order(params, data_cfg,
                                  batches=4 if FAST else 12).order
    print(f"# tier-A CNN pretrained in {time.time()-t0:.0f}s, "
          f"cloud-only acc={cloud_acc:.3f} (P={cnn_cfg.split_p} channels)",
          flush=True)
    _SYSTEM = (cnn_cfg, data_cfg, params, order, cloud_acc)
    return _SYSTEM


def _train_and_eval(c: int, bits: int, *, consolidation=True, backend="zlib",
                    eval_batches=None):
    """Train a BaF model for (C, n); return (accuracy, mean bits/img, stats)."""
    from repro.core.split import SplitInferenceEngine
    from repro.data.synthetic import shapes_batch_iterator
    from repro.train.baf_trainer import train_baf
    cnn_cfg, data_cfg, params, order, _ = tier_a_system()
    steps = 80 if FAST else 400
    res = train_baf(params, cnn_cfg, data_cfg, order[:c], bits=bits,
                    hidden=16, steps=steps, verbose=False)
    eng = SplitInferenceEngine(params, res.baf_params, res.sel_idx, bits=bits,
                               backend=backend, consolidation=consolidation)
    it = shapes_batch_iterator(data_cfg, seed=10_000)   # same eval stream as eval_cnn
    accs, tot_bits, raw_bits, ent_bits = [], [], [], []
    psnrs, kls = [], []
    nb = eval_batches or (5 if FAST else 15)
    for i in range(nb):
        img, labels = next(it)
        logits, stats = eng(img)
        accs.append(float(jnp.mean(jnp.argmax(logits, -1) == labels)))
        tot_bits.append(stats.total_bits / img.shape[0])
        raw_bits.append(stats.raw_bits / img.shape[0])
        ent_bits.append(stats.entropy_bits / img.shape[0])
        if i < 4:                  # continuous degradation metrics
            psnr, kl = eng.fidelity(img)
            psnrs.append(psnr)
            kls.append(kl)
    return (float(np.mean(accs)), float(np.mean(tot_bits)),
            {"raw_bits": float(np.mean(raw_bits)),
             "entropy_bits": float(np.mean(ent_bits)),
             "psnr_db": float(np.mean(psnrs)),
             "logit_kl": float(np.mean(kls))})


# ---------------------------------------------------------------------------
# Fig. 3 — accuracy vs number of channels (n = 8)
# ---------------------------------------------------------------------------

def bench_channel_sweep():
    cnn_cfg, _, _, _, cloud_acc = tier_a_system()
    p = cnn_cfg.split_p
    sweep = [c for c in (4, 8, 16, 32, 64) if c <= p]
    out = []
    for c in sweep:
        t0 = time.perf_counter()
        acc, bits, extra = _train_and_eval(c, 8)
        us = (time.perf_counter() - t0) * 1e6
        out.append({"C": c, "acc": acc, "cloud_acc": cloud_acc,
                    "bits_per_img": bits, **extra})
        _row(f"fig3_channels_C{c}", us,
             f"acc={acc:.3f};cloud={cloud_acc:.3f};dacc={cloud_acc-acc:+.3f};"
             f"psnr={extra['psnr_db']:.1f}dB;kl={extra['logit_kl']:.4f}")
    RESULTS["fig3_channel_sweep"] = out


# ---------------------------------------------------------------------------
# Fig. 4 — accuracy + wire bits vs quantizer depth (C = P/4, paper's C=64/256)
# ---------------------------------------------------------------------------

def bench_bit_sweep():
    cnn_cfg, _, _, _, cloud_acc = tier_a_system()
    c = max(4, cnn_cfg.split_p // 4)
    out = []
    for n in (2, 3, 4, 5, 6, 8):
        t0 = time.perf_counter()
        acc, bits, extra = _train_and_eval(c, n)
        us = (time.perf_counter() - t0) * 1e6
        out.append({"n": n, "C": c, "acc": acc, "bits_per_img": bits, **extra})
        _row(f"fig4_bits_n{n}", us,
             f"acc={acc:.3f};bits/img={bits:.0f};dacc={cloud_acc-acc:+.3f};"
             f"psnr={extra['psnr_db']:.1f}dB;kl={extra['logit_kl']:.4f}")
    RESULTS["fig4_bit_sweep"] = out


# ---------------------------------------------------------------------------
# Fig. 4 — codec comparison + the [4]-style all-channels baseline
# ---------------------------------------------------------------------------

def bench_codec():
    from repro.core import codec as wire
    from repro.core.quant import compute_quant_params, quantize
    from repro.core.tiling import tile_batch
    from repro.data.synthetic import shapes_batch_iterator
    from repro.models.cnn import cnn_edge
    cnn_cfg, data_cfg, params, order, _ = tier_a_system()
    img, _ = next(shapes_batch_iterator(data_cfg, seed=20_000))
    z = jax.jit(lambda p, i: cnn_edge(p, i)[1])(params, img)
    b = img.shape[0]
    out = {}
    c = max(4, cnn_cfg.split_p // 4)
    z_sel = z[..., jnp.asarray(order[:c])]
    qp = compute_quant_params(z_sel, 8, per_example=True)
    codes = np.asarray(quantize(z_sel, qp))
    tiled = np.asarray(tile_batch(jnp.asarray(codes)))
    stream = tiled.reshape(-1, tiled.shape[-1])
    for backend in ("raw", "zlib"):
        t0 = time.perf_counter()
        enc = wire.encode(stream, qp, backend=backend)
        us = (time.perf_counter() - t0) * 1e6
        out[backend] = enc.total_bits() / b
        _row(f"codec_{backend}_C{c}", us, f"bits/img={out[backend]:.0f}")
    out["entropy_floor"] = wire.empirical_entropy_bits(codes, 8) / b + c * 32
    _row(f"codec_entropy_floor_C{c}", 0.0,
         f"bits/img={out['entropy_floor']:.0f}")
    # [4]-style baseline: ALL P channels, 8-bit, same entropy coder
    qp_all = compute_quant_params(z, 8, per_example=True)
    codes_all = np.asarray(quantize(z, qp_all))
    t0 = time.perf_counter()
    enc_all = wire.encode(codes_all, qp_all, backend="zlib")
    us = (time.perf_counter() - t0) * 1e6
    out["all_channels_8bit"] = enc_all.total_bits() / b
    _row("codec_all_channels_8bit", us,
         f"bits/img={out['all_channels_8bit']:.0f};"
         f"subset_saving={1 - out['zlib']/out['all_channels_8bit']:.1%}")
    RESULTS["codec"] = out


# ---------------------------------------------------------------------------
# eq. (6) — consolidation ablation
# ---------------------------------------------------------------------------

def bench_consolidation():
    cnn_cfg, _, _, _, cloud_acc = tier_a_system()
    c = max(4, cnn_cfg.split_p // 4)
    out = []
    for cons in (True, False):
        t0 = time.perf_counter()
        acc, bits, extra2 = _train_and_eval(c, 3, consolidation=cons)
        us = (time.perf_counter() - t0) * 1e6
        out.append({"consolidation": cons, "n": 3, "C": c, "acc": acc,
                    **extra2})
        _row(f"consolidation_{'on' if cons else 'off'}", us,
             f"acc={acc:.3f};psnr={extra2['psnr_db']:.2f}dB;"
             f"kl={extra2['logit_kl']:.4f}")
    RESULTS["consolidation"] = out


# ---------------------------------------------------------------------------
# Kernel hot paths — µs/call on this host + derived bandwidth model
# ---------------------------------------------------------------------------

def bench_kernels():
    from repro.core.quant import compute_quant_params, quantize
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    # the paper's split tensor: (B=1, 64*64, 256)
    x = jnp.asarray(rng.normal(size=(1, 4096, 256)).astype(np.float32))

    def two_pass(x):
        qp = compute_quant_params(x, 8, per_example=True)
        return quantize(x, qp)

    us2 = _timeit(jax.jit(two_pass), x)
    _row("quantize_twopass_jnp", us2, "HBM-model=2 reads+1 write")
    usf = _timeit(partial(ops.quantize_fused, bits=8), x)
    _row("quantize_fused_pallas_interp", usf,
         "HBM-model=1 read+1 write (interpret mode; timing not indicative)")
    # bandwidth model at the TPU target: bytes moved per variant
    nbytes = x.size * 4
    RESULTS["kernels"] = {
        "quantize_twopass_us": us2, "quantize_fused_us": usf,
        "hbm_bytes_twopass": 2 * nbytes + x.size,
        "hbm_bytes_fused": nbytes + x.size,
        "model_speedup_at_roofline": (2 * nbytes + x.size) / (nbytes + x.size),
    }
    _row("quantize_bandwidth_model", 0.0,
         f"fused_moves={(nbytes + x.size)/1e6:.1f}MB;"
         f"twopass={(2*nbytes + x.size)/1e6:.1f}MB;"
         f"roofline_speedup={RESULTS['kernels']['model_speedup_at_roofline']:.2f}x")

    # consolidation kernel
    codes, qp = ops.quantize_fused(x, 8)
    est = x + 0.1
    usc = _timeit(partial(ops.consolidate_fused, bits=8), est, codes,
                  qp.mins, qp.maxs)
    _row("consolidate_fused_pallas_interp", usc, "eq6 fused clip")

    # attention/scan engines at smoke scale (jnp paths that the models run)
    from repro.models.attention import blocked_attention
    q = jnp.asarray(rng.normal(size=(2, 512, 8, 64)).astype(np.float32))
    usa = _timeit(jax.jit(lambda q: blocked_attention(q, q, q, causal=True)), q)
    _row("blocked_attention_jnp_s512", usa, "O(bq*S) score buffer")
    from repro.models.linear_attention import chunked_linear_attention
    ld = -jnp.abs(jnp.asarray(
        rng.normal(size=(2, 512, 8, 1)).astype(np.float32)))
    scan_fn = jax.jit(lambda q, ld: chunked_linear_attention(
        q, q, q, ld, chunk=64, mode="ssm")[0])
    uss = _timeit(scan_fn, q, ld)
    _row("chunked_linear_scan_jnp_s512", uss, "O(S) state passing")


# ---------------------------------------------------------------------------

BENCHES = {
    "channel_sweep": bench_channel_sweep,
    "bit_sweep": bench_bit_sweep,
    "codec": bench_codec,
    "consolidation": bench_consolidation,
    "kernels": bench_kernels,
}


def _flatten_numeric(node, prefix="", out=None) -> dict:
    """RESULTS tree -> flat dotted-key dict of numeric leaves; list entries
    key by their most identifying field (C/n/consolidation) when present."""
    out = {} if out is None else out
    if isinstance(node, dict):
        for k, v in node.items():
            _flatten_numeric(v, f"{prefix}.{k}" if prefix else str(k), out)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            tag = i
            if isinstance(v, dict):
                for field in ("C", "n", "consolidation"):
                    if field in v:
                        tag = f"{field}{v[field]}"
                        break
            _flatten_numeric(v, f"{prefix}.{tag}", out)
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        out[prefix] = float(node)
    return out


def _write_bench_record(names: list[str]) -> None:
    from repro.obs.bench import bench_record, metric, write_bench
    metrics = {k: metric(v, tolerance=None)       # trained-model numbers are
               for k, v in _flatten_numeric(RESULTS).items()}  # host/seed-
    rec = bench_record(                           # sensitive: trajectory only
        "paper",
        config={"fast": FAST, "benches": names},
        metrics=metrics)
    path = os.path.join(os.path.dirname(__file__), "BENCH_paper.json")
    write_bench(path, rec)
    print(f"# wrote {path}")


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()
    path = os.path.join(os.path.dirname(__file__), "results.json")
    with open(path, "w") as f:
        json.dump(RESULTS, f, indent=1)
    print(f"# wrote {path}")
    _write_bench_record(names)


if __name__ == '__main__':
    main()
